//! Shard-parallel trace replay: the Fig 3 request stream partitioned across
//! the shards of a [`ShardedCache`] and replayed on `std::thread::scope`
//! workers — the concurrent-workload harness behind `repro sharded` and the
//! `bench_sharded` throughput case.
//!
//! Two-phase design keeps the batched SVM inference per-shard-safe:
//!
//! 1. **Classify (single-threaded).** Walk the trace once, training the
//!    in-process SMO backend on the request-awareness labels (§5.1
//!    scenario 1) and batch-scoring every request's feature vector. The
//!    backend is never shared across threads — predictions come out as a
//!    plain `Vec<Option<bool>>`.
//! 2. **Replay (shard-parallel).** Partition request indices by
//!    `shard_of(block, n)` and hand each shard's slice — in original trace
//!    order — to its own scoped worker. Each worker drives the cache
//!    through a [`ReadHandle`]: hits resolve against the lock-free read
//!    view and recency updates drain in batches per the cache's
//!    [`RecencyConfig`] (`cache::read_path`). With one shard — and with
//!    the default immediate-drain config at any shard count — the replay
//!    is bit-identical to the sequential locked path (property-tested in
//!    rust/tests/property_sharded.rs and rust/tests/property_read_path.rs).
//!
//! One options-struct API ([`ReplayOptions`]) replaces the former
//! `run_with_classes` / `run_with_admission` / `run_observed` /
//! `replay_on_shards` / `replay_on_shards_observed` /
//! `replay_with_stats_readers` sprawl:
//!
//! | old entry point              | now |
//! |------------------------------|-----|
//! | `run_with_classes(p,s,c,t,cl)` | `replay(p,s,c,t, &ReplayOptions::new().classes(cl))` |
//! | `run_with_admission(.., adm, ..)` | `…​.admission(adm)` |
//! | `run_observed(.., kernel, batch, reg, cfg)` | `…​.classify(kernel, batch).observe(reg, cfg)` |
//! | `replay_on_shards(cache, t, cl)` | `drive(cache, t, &ReplayOptions::new().classes(cl))` |
//! | `replay_on_shards_observed(..)` | `drive` with `.scored(..).observe(..)` |
//! | `replay_with_stats_readers(.., n)` | `drive` with `.readers(n)` |

use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::sync::atomic::{AtomicBool, Ordering};

use crate::cache::read_path::RecencyConfig;
use crate::cache::sharded::{shard_of, ReadHandle, ShardStats, ShardedCache};
use crate::cache::{AccessContext, CacheBuilder, EvictCause};
use crate::hdfs::BlockId;
use crate::obs::{
    merge_audits, merge_series, AuditEntry, EvictionAudit, MetricClass, MetricsRegistry,
    ObsConfig, RunObservations, WindowSeries,
};
use crate::runtime::{RustBackend, SvmBackend};
use crate::sim::parallel::{run_fanout, FanoutOptions};
use crate::svm::features::{BlockStatsTracker, FeatureVec};
use crate::svm::KernelKind;
use crate::util::fasthash::IdHashMap;
use crate::util::table::{fmt_f, Table};
use crate::workload::BlockRequest;

/// Outcome of one shard-parallel replay.
#[derive(Debug, Clone)]
pub struct ShardedReplayReport {
    /// Replacement policy replayed (registry name, e.g. `"h-svm-lru"`).
    pub policy: String,
    /// Admission policy in front of every shard ("always" = none).
    pub admission: String,
    /// Shard count of the cache the trace was replayed against.
    pub shards: usize,
    /// Merged counters (hit ratio of the whole replay).
    pub stats: ShardStats,
    /// Per-shard counters, in shard order.
    pub per_shard: Vec<ShardStats>,
    /// Wall-clock time of the parallel replay phase only.
    pub wall: Duration,
}

impl ShardedReplayReport {
    /// Replay throughput: requests over the parallel phase's wall time.
    pub fn requests_per_sec(&self) -> f64 {
        self.stats.requests as f64 / self.wall.as_secs_f64().max(1e-12)
    }

    /// Hit ratio of the whole replay, from the merged counters (the one
    /// place it is computed — callers must not rederive it per shard).
    pub fn hit_ratio(&self) -> f64 {
        self.stats.hit_ratio()
    }
}

/// What concurrent lock-free stats readers observed during a replay (the
/// [`ReplayOptions::readers`] knob).
#[derive(Debug, Clone, Copy, Default)]
pub struct StatsReaderReport {
    /// Concurrent reader threads that ran during the replay.
    pub readers: usize,
    /// Merged-stats snapshots taken across all readers while the shard
    /// workers were replaying.
    pub snapshots: u64,
    /// Snapshots that violated an internal-consistency invariant
    /// (`hits + misses == requests`, `used <= capacity`, per-shard
    /// coupling). Must be 0 — the seqlock guarantees it.
    pub inconsistencies: u64,
}

/// Where a replay's per-request SVM predictions come from.
#[derive(Clone, Copy, Default)]
pub enum Predictions<'a> {
    /// No predictions (pure baseline policies).
    #[default]
    None,
    /// Precomputed boolean classes, index-aligned with the trace.
    Classes(&'a [Option<bool>]),
    /// Precomputed features + raw decision scores (classes are
    /// `score > 0.0`) — what the audit ring records.
    Scored {
        /// Per-request pre-access feature vectors.
        features: &'a [FeatureVec],
        /// Per-request decision scores (`None` = untrainable trace).
        scores: &'a [Option<f32>],
    },
    /// Run the single-threaded classifier pass ([`classify_trace_scored`])
    /// before the replay, keeping features + scores for the audit ring.
    Classify {
        /// SVM kernel for the SMO backend.
        kernel: KernelKind,
        /// Batch size of the scoring pass.
        batch: usize,
    },
}

/// Options for [`replay`] / [`drive`] — one struct instead of a driver
/// variant per combination. The default replays without predictions,
/// telemetry or readers, with immediate recency drains: exactly the old
/// `run_with_classes(policy, …, &[])`.
#[derive(Clone, Copy, Default)]
pub struct ReplayOptions<'a> {
    /// Admission policy in front of every shard ([`replay`] only —
    /// [`drive`] replays whatever cache it is given).
    pub admission: Option<&'a str>,
    /// Per-request prediction source.
    pub predictions: Predictions<'a>,
    /// Telemetry: per-worker window series + eviction audit merged into a
    /// [`RunObservations`], plus registry histograms for eviction scan
    /// work and access latency. Never perturbs cache behavior.
    pub observe: Option<(&'a MetricsRegistry, ObsConfig)>,
    /// Concurrent lock-free stats readers hammering `stats()` / `used()` /
    /// `snapshot_of()` for the whole replay (0 = none).
    pub readers: usize,
    /// Contain worker panics ([`FanoutOptions::resilient`]): surviving
    /// shards report, a panicked shard keeps its counters as of the
    /// panic.
    pub resilient: bool,
    /// Recency-batching knobs for the cache [`replay`] builds
    /// ([`drive`] uses the cache's own config).
    pub recency: RecencyConfig,
}

impl<'a> ReplayOptions<'a> {
    /// The behavior-preserving defaults (see the struct docs).
    pub fn new() -> Self {
        Self::default()
    }

    /// Admission policy by registry name ([`replay`] only).
    pub fn admission(mut self, name: &'a str) -> Self {
        self.admission = Some(name);
        self
    }

    /// Attach precomputed per-request classes.
    pub fn classes(mut self, classes: &'a [Option<bool>]) -> Self {
        self.predictions = Predictions::Classes(classes);
        self
    }

    /// Attach precomputed features + decision scores.
    pub fn scored(mut self, features: &'a [FeatureVec], scores: &'a [Option<f32>]) -> Self {
        self.predictions = Predictions::Scored { features, scores };
        self
    }

    /// Run the classifier pass before replaying.
    pub fn classify(mut self, kernel: KernelKind, batch: usize) -> Self {
        self.predictions = Predictions::Classify { kernel, batch };
        self
    }

    /// Attach the telemetry layer.
    pub fn observe(mut self, registry: &'a MetricsRegistry, cfg: ObsConfig) -> Self {
        self.observe = Some((registry, cfg));
        self
    }

    /// Run `n` concurrent lock-free stats readers during the replay.
    pub fn readers(mut self, n: usize) -> Self {
        self.readers = n;
        self
    }

    /// Contain worker panics instead of propagating them.
    pub fn resilient(mut self, contained: bool) -> Self {
        self.resilient = contained;
        self
    }

    /// Recency-batching knobs for the cache [`replay`] builds.
    pub fn recency(mut self, cfg: RecencyConfig) -> Self {
        self.recency = cfg;
        self
    }
}

/// Everything one replay produced: the report plus whatever optional
/// layers [`ReplayOptions`] enabled.
#[derive(Debug)]
pub struct ReplayOutcome {
    /// Merged + per-shard counters and replay wall time.
    pub report: ShardedReplayReport,
    /// Telemetry, when [`ReplayOptions::observe`] was set.
    pub observations: Option<RunObservations>,
    /// Reader consistency report, when [`ReplayOptions::readers`] > 0.
    pub readers: Option<StatsReaderReport>,
}

/// The feature pass shared by every trace classifier: walk `trace` once
/// with a fresh [`BlockStatsTracker`], returning each request's
/// *pre-access* feature vector plus the full request-awareness dataset
/// (features labeled with `reused_later`).
///
/// Per-block feature state depends only on that block's own history, and
/// a block's requests all route to one shard — so a per-shard tracker fed
/// its shard's requests in trace order reproduces these vectors exactly.
/// That invariant is what lets the online replay (`experiments::
/// online_sharded`) compute features concurrently yet stay bit-identical
/// to this single-threaded pass (property-tested in
/// rust/tests/property_online.rs).
pub fn trace_dataset(trace: &[BlockRequest]) -> (Vec<FeatureVec>, crate::svm::Dataset) {
    let block_size = trace.iter().map(|r| r.size).max().unwrap_or(1);
    let mut tracker = BlockStatsTracker::new(block_size);
    let mut dataset = crate::svm::Dataset::new();
    let mut features = Vec::with_capacity(trace.len());
    for req in trace {
        let f = tracker.features(
            req.block,
            req.kind,
            req.size,
            req.affinity,
            req.recompute_cost,
            req.time,
        );
        dataset.push(f, req.reused_later);
        features.push(f);
        tracker.record_access(req.block, 0, req.time);
    }
    (features, dataset)
}

/// Phase 1: single-threaded classifier pass. Trains the SMO fallback on the
/// trace's request-awareness labels, then batch-scores every request's
/// feature vector (chunks of `batch`). Returns one prediction per request;
/// all `None` when the trace is single-class (classifier untrainable).
pub fn classify_trace(
    trace: &[BlockRequest],
    kernel: KernelKind,
    batch: usize,
) -> Result<Vec<Option<bool>>> {
    let (_, scores) = classify_trace_scored(trace, kernel, batch)?;
    Ok(scores.into_iter().map(|s| s.map(|v| v > 0.0)).collect())
}

/// [`classify_trace`] keeping the raw decision scores and the per-request
/// feature vectors — the audit ring records both, and the boolean classes
/// are just `score > 0.0`.
pub fn classify_trace_scored(
    trace: &[BlockRequest],
    kernel: KernelKind,
    batch: usize,
) -> Result<(Vec<FeatureVec>, Vec<Option<f32>>)> {
    let mut backend = RustBackend::new(kernel);
    let (features, dataset) = trace_dataset(trace);
    if dataset.n_positive() == 0 || dataset.n_positive() == dataset.len() {
        let scores = vec![None; trace.len()];
        return Ok((features, scores));
    }
    backend.train(&dataset).context("training classifier pass")?;

    // Scoring pass: batch through the backend, never from a worker thread.
    let mut scores = Vec::with_capacity(trace.len());
    for chunk in features.chunks(batch.max(1)) {
        let chunk_scores = backend
            .decision_batch(chunk)
            .context("scoring classifier pass")?;
        scores.extend(chunk_scores.into_iter().map(Some));
    }
    Ok((features, scores))
}

/// Request indices of `trace` grouped by owning shard, preserving trace
/// order within each shard.
fn partition_by_shard(trace: &[BlockRequest], n: usize) -> Vec<Vec<usize>> {
    let mut partitions: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, req) in trace.iter().enumerate() {
        partitions[shard_of(req.block, n)].push(i);
    }
    partitions
}

/// The per-request [`AccessContext`] of a trace replay.
fn request_ctx(req: &BlockRequest, predicted: Option<bool>) -> AccessContext {
    AccessContext {
        time: req.time,
        size: req.size,
        kind: req.kind,
        file: req.block.0, // trace blocks are their own files
        file_width: 1,
        file_complete: false,
        affinity: req.affinity,
        predicted_reuse: predicted,
        recompute_cost: req.recompute_cost,
    }
}

/// Replay one shard's request indices through a worker's [`ReadHandle`].
fn replay_slice(
    handle: &mut ReadHandle<'_>,
    trace: &[BlockRequest],
    classes: &[Option<bool>],
    indices: &[usize],
) {
    for &i in indices {
        let req = &trace[i];
        let ctx = request_ctx(req, classes.get(i).copied().flatten());
        handle.access_or_insert(req.block, &ctx);
    }
}

/// Phase 2 against a caller-built cache: replay `trace`, one scoped worker
/// per shard, each driving the cache through its own [`ReadHandle`]
/// (draining per the cache's [`RecencyConfig`]). [`ReplayOptions`] selects
/// the optional layers; `admission` and `recency` are construction knobs
/// and ignored here — see [`replay`] for the cache-building entry point.
///
/// Telemetry notes (the `observe` layer): each worker keeps its own
/// window series + audit ring, merged deterministically at the end;
/// eviction scan work and access latency go into per-shard registry
/// histograms. Ground truth for the confusion counts comes from each
/// worker's last-access map: a block's requests all route to one shard,
/// and an eviction happens after the victim's last access and before its
/// next request, so `reused_later` of the victim's most recent request IS
/// "was it requested again after this eviction". Observation never
/// perturbs the cache — it reads the [`crate::cache::AccessOutcome`] the
/// access already returns.
// Wall-clock exception: replay wall time and access latency are
// reporting-only / Volatile metrics — see clippy.toml and
// rust/tests/lint_invariants.rs.
#[allow(clippy::disallowed_methods)]
pub fn drive(
    cache: &ShardedCache,
    trace: &[BlockRequest],
    opts: &ReplayOptions<'_>,
) -> Result<ReplayOutcome> {
    let n = cache.n_shards();
    let partitions = partition_by_shard(trace, n);

    // Resolve the prediction source into (features, scores, classes)
    // slices; the classifier pass (if requested) runs before the timed
    // replay phase, exactly like the old two-phase drivers.
    let computed: Option<(Vec<FeatureVec>, Vec<Option<f32>>)> = match opts.predictions {
        Predictions::Classify { kernel, batch } => {
            Some(classify_trace_scored(trace, kernel, batch)?)
        }
        _ => None,
    };
    let (features, scores): (&[FeatureVec], &[Option<f32>]) = match (&opts.predictions, &computed)
    {
        (Predictions::Scored { features, scores }, _) => (features, scores),
        (Predictions::Classify { .. }, Some((f, s))) => (f.as_slice(), s.as_slice()),
        _ => (&[], &[]),
    };
    let derived: Vec<Option<bool>>;
    let classes: &[Option<bool>] = match opts.predictions {
        Predictions::Classes(classes) => classes,
        Predictions::None => &[],
        _ => {
            derived = scores.iter().map(|s| s.map(|v| v > 0.0)).collect();
            &derived
        }
    };

    let hists = opts.observe.map(|(registry, _)| {
        (
            registry.histogram("evict.scan_steps", MetricClass::Deterministic, n),
            registry.histogram("replay.access_ns", MetricClass::Volatile, n),
        )
    });

    let worker = |w: usize| {
        let mut handle = cache.read_handle();
        let (Some((scan_hist, access_ns)), Some((_, cfg))) = (&hists, opts.observe) else {
            replay_slice(&mut handle, trace, classes, &partitions[w]);
            return None;
        };
        let mut windows = WindowSeries::new(cfg.window_us);
        let mut audit = EvictionAudit::new(cfg.audit_every, cfg.audit_cap);
        let mut last: IdHashMap<BlockId, usize> = IdHashMap::default();
        for &i in &partitions[w] {
            let req = &trace[i];
            let ctx = request_ctx(req, classes.get(i).copied().flatten());
            let t0 = access_ns.is_active().then(Instant::now);
            let outcome = handle.access_or_insert(req.block, &ctx);
            if let Some(t0) = t0 {
                access_ns.record(w, t0.elapsed().as_nanos() as u64);
            }
            if !outcome.hit {
                scan_hist.record(w, u64::from(outcome.scan_steps));
            }
            // This worker is shard w's only writer (buffered hits count at
            // read time, mutations drain under its own lock), so the
            // lock-free snapshot it reads back is its own deterministic
            // state.
            let occupancy = cache.snapshot_of(w).blocks;
            let win = windows.at(req.time);
            win.requests += 1;
            win.hits += u64::from(outcome.hit);
            win.insertions += u64::from(outcome.inserted);
            win.occupancy_end = occupancy;
            for (victim, cause) in outcome.evicted.iter().zip(&outcome.causes) {
                match cause {
                    EvictCause::Capacity => win.evict_capacity += 1,
                    EvictCause::AdmissionDuel => win.evict_admission += 1,
                    EvictCause::CostTieBreak => win.evict_cost_tie += 1,
                }
                if let Some(li) = last.remove(victim) {
                    let actual = trace[li].reused_later;
                    let predicted = classes.get(li).copied().flatten();
                    match predicted {
                        Some(true) if actual => win.tp += 1,
                        Some(true) => win.fp += 1,
                        Some(false) if actual => win.fn_ += 1,
                        Some(false) => win.tn += 1,
                        None => {}
                    }
                    audit.observe(|| AuditEntry {
                        at: req.time,
                        block: *victim,
                        cause: *cause,
                        features: features.get(li).copied().unwrap_or_default(),
                        score: scores.get(li).copied().flatten().unwrap_or(0.0),
                        predicted,
                        actual,
                    });
                }
            }
            last.insert(req.block, i);
        }
        Some((windows.finish(), audit))
    };

    let t0 = Instant::now();
    let (slots, readers) = if opts.readers > 0 {
        let n_readers = opts.readers;
        let monitor = |done: &AtomicBool| {
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..n_readers)
                    .map(|_| {
                        scope.spawn(move || {
                            let mut snapshots = 0u64;
                            let mut inconsistencies = 0u64;
                            let mut last_requests = 0u64;
                            // do-while: at least one snapshot even when the
                            // replay finishes before the reader's first pass.
                            loop {
                                let merged = cache.stats();
                                let mut ok = merged.hits + merged.misses == merged.requests
                                    && cache.used() <= cache.capacity()
                                    && merged.requests >= last_requests;
                                last_requests = merged.requests;
                                for s in 0..n {
                                    let snap = cache.snapshot_of(s);
                                    ok &= snap.stats.hits + snap.stats.misses
                                        == snap.stats.requests;
                                }
                                snapshots += 1;
                                inconsistencies += u64::from(!ok);
                                // Acquire: pairs with the harness's Release
                                // store; the workers' final counters precede
                                // this last observation.
                                if done.load(Ordering::Acquire) {
                                    break;
                                }
                                std::thread::yield_now();
                            }
                            (snapshots, inconsistencies)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("stats reader panicked"))
                    .fold((0u64, 0u64), |acc, (s, i)| (acc.0 + s, acc.1 + i))
            })
        };
        let rep = run_fanout(
            n,
            &worker,
            FanoutOptions::new().monitor(monitor).resilient(opts.resilient),
        );
        let (snapshots, inconsistencies) = rep.monitor.expect("monitor configured");
        (
            rep.workers,
            Some(StatsReaderReport { readers: n_readers, snapshots, inconsistencies }),
        )
    } else {
        let rep = run_fanout(n, &worker, FanoutOptions::new().resilient(opts.resilient));
        (rep.workers, None)
    };
    let wall = t0.elapsed();

    // Per-shard counters read post-join: shard w's stats have exactly one
    // writer (its worker), so this equals what the worker saw at its end.
    let per_shard: Vec<ShardStats> = (0..n).map(|w| cache.stats_of(w)).collect();
    let mut stats = ShardStats::default();
    for s in &per_shard {
        stats.merge(s);
    }

    let observations = opts.observe.map(|(_, cfg)| {
        let mut window_parts = Vec::with_capacity(n);
        let mut audit_parts = Vec::with_capacity(n);
        for slot in slots.into_iter().flatten().flatten() {
            let (windows, audit) = slot;
            window_parts.push(windows);
            audit_parts.push(audit);
        }
        let (audit, audit_seen) = merge_audits(audit_parts);
        RunObservations {
            windows: merge_series(window_parts),
            audit,
            audit_seen,
            audit_every: cfg.audit_every.max(1),
        }
    });

    Ok(ReplayOutcome {
        report: ShardedReplayReport {
            policy: cache.policy_name().to_string(),
            admission: cache.admission_name().to_string(),
            shards: n,
            stats,
            per_shard,
            wall,
        },
        observations,
        readers,
    })
}

/// Build a `shards`-way cache of the registry policy `policy` (honoring
/// [`ReplayOptions::admission`] and [`ReplayOptions::recency`]) and
/// [`drive`] `trace` against it.
pub fn replay(
    policy: &str,
    shards: usize,
    capacity: u64,
    trace: &[BlockRequest],
    opts: &ReplayOptions<'_>,
) -> Result<ReplayOutcome> {
    let admission = opts.admission.unwrap_or("always");
    let cache = CacheBuilder::new()
        .policy(policy)
        .admission(admission)
        .shards(shards.max(1))
        .capacity(capacity)
        .recency(opts.recency)
        .build()
        .with_context(|| format!("building {shards}-shard {policy:?}/{admission:?} cache"))?;
    drive(&cache, trace, opts)
}

/// Full pipeline for one shard count: classify once, then replay.
pub fn run(
    policy: &str,
    shards: usize,
    capacity: u64,
    trace: &[BlockRequest],
) -> Result<ShardedReplayReport> {
    let classes = classify_trace(trace, KernelKind::Rbf, 64)?;
    let outcome = replay(
        policy,
        shards,
        capacity,
        trace,
        &ReplayOptions::new().classes(&classes),
    )?;
    Ok(outcome.report)
}

/// Sweep several shard counts over the same trace. The classifier pass
/// runs once — predictions do not depend on the shard count — so the sweep
/// cost is dominated by the replays themselves.
pub fn run_sweep(
    policy: &str,
    shard_counts: &[usize],
    capacity: u64,
    trace: &[BlockRequest],
) -> Result<Vec<ShardedReplayReport>> {
    let classes = classify_trace(trace, KernelKind::Rbf, 64)?;
    shard_counts
        .iter()
        .map(|&n| {
            let outcome =
                replay(policy, n, capacity, trace, &ReplayOptions::new().classes(&classes))?;
            Ok(outcome.report)
        })
        .collect()
}

/// Render a shard-count sweep as a table (the `repro sharded` output).
pub fn render(reports: &[ShardedReplayReport]) -> Table {
    let mut t = Table::new(vec![
        "policy",
        "shards",
        "hit ratio",
        "evictions",
        "replay wall (ms)",
        "req/s",
    ]);
    for r in reports {
        t.add_row(vec![
            r.policy.clone(),
            r.shards.to_string(),
            fmt_f(r.hit_ratio(), 4),
            r.stats.evictions.to_string(),
            fmt_f(r.wall.as_secs_f64() * 1e3, 2),
            format!("{:.0}", r.requests_per_sec()),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::DEFAULT_AUDIT_EVERY;
    use crate::util::bytes::MB;
    use crate::workload::fig3_trace;

    // One-line parity wrappers re-expressing the removed driver names over
    // the options API — the legacy tests below run against these, pinning
    // the collapsed entry points to the old contracts.
    fn run_with_classes(
        policy: &str,
        shards: usize,
        capacity: u64,
        trace: &[BlockRequest],
        classes: &[Option<bool>],
    ) -> Result<ShardedReplayReport> {
        Ok(replay(policy, shards, capacity, trace, &ReplayOptions::new().classes(classes))?
            .report)
    }

    #[allow(clippy::too_many_arguments)]
    fn run_observed(
        policy: &str,
        admission: &str,
        shards: usize,
        capacity: u64,
        trace: &[BlockRequest],
        kernel: KernelKind,
        batch: usize,
        registry: &MetricsRegistry,
        cfg: ObsConfig,
    ) -> Result<(ShardedReplayReport, RunObservations)> {
        let opts = ReplayOptions::new()
            .admission(admission)
            .classify(kernel, batch)
            .observe(registry, cfg);
        let out = replay(policy, shards, capacity, trace, &opts)?;
        Ok((out.report, out.observations.expect("observe configured")))
    }

    fn replay_with_stats_readers(
        cache: &ShardedCache,
        trace: &[BlockRequest],
        classes: &[Option<bool>],
        n_readers: usize,
    ) -> (Vec<ShardStats>, StatsReaderReport) {
        let opts = ReplayOptions::new().classes(classes).readers(n_readers);
        let out = drive(cache, trace, &opts).expect("no classifier pass to fail");
        (out.report.per_shard, out.readers.unwrap_or_default())
    }

    fn lru_cache(shards: usize, capacity: u64) -> ShardedCache {
        CacheBuilder::new().policy("lru").shards(shards).capacity(capacity).build().unwrap()
    }

    #[test]
    fn classifier_pass_labels_every_request() {
        let trace = fig3_trace(64 * MB, 3);
        let classes = classify_trace(&trace, KernelKind::Rbf, 64).unwrap();
        assert_eq!(classes.len(), trace.len());
        assert!(classes.iter().any(|c| c.is_some()), "mixed trace must train");
        // Both classes must be predicted somewhere on the pollution trace.
        assert!(classes.iter().any(|c| *c == Some(true)));
        assert!(classes.iter().any(|c| *c == Some(false)));
    }

    #[test]
    fn one_shard_replay_matches_sequential_replay() {
        let trace = fig3_trace(64 * MB, 5);
        let classes = classify_trace(&trace, KernelKind::Rbf, 64).unwrap();
        // Sequential ground truth: the locked path, no read handle.
        let seq = CacheBuilder::new()
            .policy("h-svm-lru")
            .capacity(8 * 64 * MB)
            .build()
            .unwrap();
        for (i, req) in trace.iter().enumerate() {
            let ctx = request_ctx(req, classes[i]);
            seq.access_or_insert(req.block, &ctx);
        }
        let report = run("h-svm-lru", 1, 8 * 64 * MB, &trace).unwrap();
        assert_eq!(report.stats, seq.stats());
        assert_eq!(report.per_shard.len(), 1);
    }

    #[test]
    fn multi_shard_sweep_counts_every_request() {
        let trace = fig3_trace(64 * MB, 7);
        // 16 blocks of capacity: at 8 shards every shard still holds 2
        // blocks, enough for the Zipf-hot inputs to produce hits.
        let reports = run_sweep("lru", &[2, 4, 8], 16 * 64 * MB, &trace).unwrap();
        assert_eq!(reports.len(), 3);
        for (report, &shards) in reports.iter().zip(&[2usize, 4, 8]) {
            assert_eq!(report.shards, shards);
            assert_eq!(report.stats.requests, trace.len() as u64);
            assert_eq!(
                report.stats.hits + report.stats.misses,
                report.stats.requests
            );
            assert!(report.per_shard.iter().all(|s| s.requests > 0));
            assert!(report.stats.hit_ratio() > 0.0);
        }
    }

    #[test]
    fn unknown_policy_errors() {
        let trace = fig3_trace(64 * MB, 3);
        assert!(run("nonsense", 2, 8 * 64 * MB, &trace).is_err());
        let err = replay("lru", 2, 8 * MB, &trace, &ReplayOptions::new().admission("nope"))
            .unwrap_err();
        assert!(err.to_string().contains("cache"), "{err}");
    }

    #[test]
    fn observed_replay_matches_plain_replay_and_its_own_windows() {
        let trace = fig3_trace(64 * MB, 11);
        let registry = MetricsRegistry::new();
        let (report, obs) = run_observed(
            "h-svm-lru",
            "always",
            4,
            8 * 64 * MB,
            &trace,
            KernelKind::Rbf,
            64,
            &registry,
            ObsConfig::default(),
        )
        .unwrap();
        // Observation must not perturb the cache: same stats as the
        // plain path on the same trace/policy/predictions.
        let classes = classify_trace(&trace, KernelKind::Rbf, 64).unwrap();
        let plain = run_with_classes("h-svm-lru", 4, 8 * 64 * MB, &trace, &classes).unwrap();
        assert_eq!(report.stats, plain.stats);
        assert_eq!(report.per_shard, plain.per_shard);

        // Window sums reproduce the merged counters.
        let requests: u64 = obs.windows.iter().map(|(_, w)| w.requests).sum();
        let hits: u64 = obs.windows.iter().map(|(_, w)| w.hits).sum();
        let evictions: u64 = obs.windows.iter().map(|(_, w)| w.evictions()).sum();
        assert_eq!(requests, report.stats.requests);
        assert_eq!(hits, report.stats.hits);
        assert_eq!(evictions, report.stats.evictions);
        // Confusion counts only cover evictions whose victim was seen
        // before (all of them here) and carried a prediction.
        let labeled: u64 = obs.windows.iter().map(|(_, w)| w.labeled_evictions()).sum();
        assert!(labeled <= evictions);
        assert!(labeled > 0, "classified trace must label some evictions");

        // Audit ring: sampled every Nth eviction, each entry labeled.
        assert_eq!(obs.audit_every, DEFAULT_AUDIT_EVERY);
        assert!(obs.audit_seen > 0);
        // Each of the 4 worker rings samples ceil(seen_w / every) entries,
        // so the merged total may exceed the global ceiling by one per ring.
        assert!(obs.audit.len() as u64 <= obs.audit_seen / obs.audit_every + 4);
        assert!(!obs.audit.is_empty());
        assert!(obs.audit.windows(2).all(|p| (p[0].at, p[0].block.0)
            <= (p[1].at, p[1].block.0)));

        // The registry picked up the deterministic scan-work histogram.
        let hists = registry.hist_snapshots();
        let scan = hists
            .iter()
            .find(|(name, _, _)| name == "evict.scan_steps")
            .expect("scan histogram registered");
        assert_eq!(scan.1, MetricClass::Deterministic);
        assert_eq!(scan.2.count, report.stats.misses);
    }

    #[test]
    fn observed_replay_with_disabled_registry_still_windows() {
        let trace = fig3_trace(64 * MB, 4);
        let registry = MetricsRegistry::disabled();
        let (report, obs) = run_observed(
            "lru",
            "always",
            2,
            8 * 64 * MB,
            &trace,
            KernelKind::Rbf,
            64,
            &registry,
            ObsConfig { window_us: 500_000, audit_every: 1, audit_cap: 16 },
        )
        .unwrap();
        let requests: u64 = obs.windows.iter().map(|(_, w)| w.requests).sum();
        assert_eq!(requests, report.stats.requests);
        assert!(registry.hist_snapshots().is_empty(), "disabled registry records nothing");
        assert!(obs.audit.len() <= 2 * 16, "per-worker audit ring capacity bound");
    }

    #[test]
    fn stats_readers_see_only_consistent_snapshots() {
        let trace = fig3_trace(64 * MB, 9);
        let cache = lru_cache(4, 8 * 64 * MB);
        let (per_shard, report) = replay_with_stats_readers(&cache, &trace, &[], 2);
        assert_eq!(report.readers, 2);
        assert!(report.snapshots > 0, "readers must have observed the replay");
        assert_eq!(report.inconsistencies, 0, "seqlock snapshots must be consistent");
        let mut merged = ShardStats::default();
        for s in &per_shard {
            merged.merge(s);
        }
        assert_eq!(merged, cache.stats());
        assert_eq!(merged.requests, trace.len() as u64);
        // Reader-free path is the plain replay.
        let cache2 = lru_cache(4, 8 * 64 * MB);
        let (plain, none) = replay_with_stats_readers(&cache2, &trace, &[], 0);
        assert_eq!(none.readers, 0);
        assert_eq!(none.snapshots, 0);
        assert_eq!(plain, per_shard, "readers must not perturb the replay");
    }

    #[test]
    fn batched_recency_replay_matches_immediate_replay() {
        // One worker per shard + buffered drains: the drained event order
        // equals each worker's program order, so any batch size reproduces
        // the immediate-drain replay exactly — stats AND contents.
        let trace = fig3_trace(64 * MB, 13);
        let baseline = run_with_classes("lru", 4, 8 * 64 * MB, &trace, &[]).unwrap();
        for batch in [8usize, 256] {
            let opts = ReplayOptions::new()
                .recency(RecencyConfig::default().with_batch(batch));
            let out = replay("lru", 4, 8 * 64 * MB, &trace, &opts).unwrap();
            assert_eq!(out.report.stats, baseline.stats, "batch={batch}");
            assert_eq!(out.report.per_shard, baseline.per_shard, "batch={batch}");
        }
    }

    #[test]
    fn resilient_drive_survives_a_poisoned_replay() {
        // Resilience is plumbed through to the fan-out: a replay against a
        // healthy cache with resilient=true behaves exactly like the
        // plain one (there is nothing to contain).
        let trace = fig3_trace(64 * MB, 6);
        let cache = lru_cache(2, 8 * 64 * MB);
        let out = drive(&cache, &trace, &ReplayOptions::new().resilient(true)).unwrap();
        assert_eq!(out.report.stats.requests, trace.len() as u64);
        assert!(out.observations.is_none());
        assert!(out.readers.is_none());
    }
}
