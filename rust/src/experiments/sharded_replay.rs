//! Shard-parallel trace replay: the Fig 3 request stream partitioned across
//! the shards of a [`ShardedCache`] and replayed on `std::thread::scope`
//! workers — the concurrent-workload harness behind `repro sharded` and the
//! `bench_sharded` throughput case.
//!
//! Two-phase design keeps the batched SVM inference per-shard-safe:
//!
//! 1. **Classify (single-threaded).** Walk the trace once, training the
//!    in-process SMO backend on the request-awareness labels (§5.1
//!    scenario 1) and batch-scoring every request's feature vector. The
//!    backend is never shared across threads — predictions come out as a
//!    plain `Vec<Option<bool>>`.
//! 2. **Replay (shard-parallel).** Partition request indices by
//!    `shard_of(block, n)` and hand each shard's slice — in original trace
//!    order — to its own scoped worker. Workers touch only their shard's
//!    lock, so with one shard the replay is bit-identical to the sequential
//!    path (property-tested in rust/tests/property_sharded.rs).

use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::sync::atomic::{AtomicBool, Ordering};

use crate::cache::sharded::{shard_of, ShardStats, ShardedCache};
use crate::cache::{AccessContext, EvictCause};
use crate::hdfs::BlockId;
use crate::obs::{
    merge_audits, merge_series, AuditEntry, EvictionAudit, MetricClass, MetricsRegistry,
    ObsConfig, RunObservations, WindowSeries,
};
use crate::runtime::{RustBackend, SvmBackend};
use crate::sim::parallel::{run_sharded, run_sharded_with_monitor};
use crate::svm::features::{BlockStatsTracker, FeatureVec};
use crate::svm::KernelKind;
use crate::util::fasthash::IdHashMap;
use crate::util::table::{fmt_f, Table};
use crate::workload::BlockRequest;

/// Outcome of one shard-parallel replay.
#[derive(Debug, Clone)]
pub struct ShardedReplayReport {
    /// Replacement policy replayed (registry name, e.g. `"h-svm-lru"`).
    pub policy: String,
    /// Admission policy in front of every shard ("always" = none).
    pub admission: String,
    /// Shard count of the cache the trace was replayed against.
    pub shards: usize,
    /// Merged counters (hit ratio of the whole replay).
    pub stats: ShardStats,
    /// Per-shard counters, in shard order.
    pub per_shard: Vec<ShardStats>,
    /// Wall-clock time of the parallel replay phase only.
    pub wall: Duration,
}

impl ShardedReplayReport {
    /// Replay throughput: requests over the parallel phase's wall time.
    pub fn requests_per_sec(&self) -> f64 {
        self.stats.requests as f64 / self.wall.as_secs_f64().max(1e-12)
    }

    /// Hit ratio of the whole replay, from the merged counters (the one
    /// place it is computed — callers must not rederive it per shard).
    pub fn hit_ratio(&self) -> f64 {
        self.stats.hit_ratio()
    }
}

/// The feature pass shared by every trace classifier: walk `trace` once
/// with a fresh [`BlockStatsTracker`], returning each request's
/// *pre-access* feature vector plus the full request-awareness dataset
/// (features labeled with `reused_later`).
///
/// Per-block feature state depends only on that block's own history, and
/// a block's requests all route to one shard — so a per-shard tracker fed
/// its shard's requests in trace order reproduces these vectors exactly.
/// That invariant is what lets the online replay (`experiments::
/// online_sharded`) compute features concurrently yet stay bit-identical
/// to this single-threaded pass (property-tested in
/// rust/tests/property_online.rs).
pub fn trace_dataset(trace: &[BlockRequest]) -> (Vec<FeatureVec>, crate::svm::Dataset) {
    let block_size = trace.iter().map(|r| r.size).max().unwrap_or(1);
    let mut tracker = BlockStatsTracker::new(block_size);
    let mut dataset = crate::svm::Dataset::new();
    let mut features = Vec::with_capacity(trace.len());
    for req in trace {
        let f = tracker.features(
            req.block,
            req.kind,
            req.size,
            req.affinity,
            req.recompute_cost,
            req.time,
        );
        dataset.push(f, req.reused_later);
        features.push(f);
        tracker.record_access(req.block, 0, req.time);
    }
    (features, dataset)
}

/// Phase 1: single-threaded classifier pass. Trains the SMO fallback on the
/// trace's request-awareness labels, then batch-scores every request's
/// feature vector (chunks of `batch`). Returns one prediction per request;
/// all `None` when the trace is single-class (classifier untrainable).
pub fn classify_trace(
    trace: &[BlockRequest],
    kernel: KernelKind,
    batch: usize,
) -> Result<Vec<Option<bool>>> {
    let (_, scores) = classify_trace_scored(trace, kernel, batch)?;
    Ok(scores.into_iter().map(|s| s.map(|v| v > 0.0)).collect())
}

/// [`classify_trace`] keeping the raw decision scores and the per-request
/// feature vectors — the audit ring records both, and the boolean classes
/// are just `score > 0.0`.
pub fn classify_trace_scored(
    trace: &[BlockRequest],
    kernel: KernelKind,
    batch: usize,
) -> Result<(Vec<FeatureVec>, Vec<Option<f32>>)> {
    let mut backend = RustBackend::new(kernel);
    let (features, dataset) = trace_dataset(trace);
    if dataset.n_positive() == 0 || dataset.n_positive() == dataset.len() {
        let scores = vec![None; trace.len()];
        return Ok((features, scores));
    }
    backend.train(&dataset).context("training classifier pass")?;

    // Scoring pass: batch through the backend, never from a worker thread.
    let mut scores = Vec::with_capacity(trace.len());
    for chunk in features.chunks(batch.max(1)) {
        let chunk_scores = backend
            .decision_batch(chunk)
            .context("scoring classifier pass")?;
        scores.extend(chunk_scores.into_iter().map(Some));
    }
    Ok((features, scores))
}

/// Request indices of `trace` grouped by owning shard, preserving trace
/// order within each shard.
fn partition_by_shard(trace: &[BlockRequest], n: usize) -> Vec<Vec<usize>> {
    let mut partitions: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, req) in trace.iter().enumerate() {
        partitions[shard_of(req.block, n)].push(i);
    }
    partitions
}

/// Replay one shard's request indices against the shared cache.
fn replay_slice(
    cache: &ShardedCache,
    trace: &[BlockRequest],
    classes: &[Option<bool>],
    indices: &[usize],
) {
    for &i in indices {
        let req = &trace[i];
        let ctx = AccessContext {
            time: req.time,
            size: req.size,
            kind: req.kind,
            file: req.block.0, // trace blocks are their own files
            file_width: 1,
            file_complete: false,
            affinity: req.affinity,
            predicted_reuse: classes.get(i).copied().flatten(),
            recompute_cost: req.recompute_cost,
        };
        cache.access_or_insert(req.block, &ctx);
    }
}

/// Phase 2: replay `trace` against `cache`, one scoped worker per shard.
/// `classes[i]` is the prediction attached to request `i` (pass an empty
/// slice to replay without predictions). Each worker sees its shard's
/// requests in original trace order.
pub fn replay_on_shards(
    cache: &ShardedCache,
    trace: &[BlockRequest],
    classes: &[Option<bool>],
) -> Vec<ShardStats> {
    let n = cache.n_shards();
    let partitions = partition_by_shard(trace, n);
    run_sharded(n, |w| {
        replay_slice(cache, trace, classes, &partitions[w]);
        cache.stats_of(w)
    })
}

/// [`replay_on_shards`] with the telemetry layer attached: each worker
/// keeps its own [`WindowSeries`] + [`EvictionAudit`] (merged
/// deterministically at the end) and records eviction scan work /
/// access latency into per-shard registry histograms. Cache behavior is
/// identical to the plain replay — observation reads the
/// [`crate::cache::AccessOutcome`] the access already returns.
///
/// Ground truth for the confusion counts comes from each worker's
/// last-access map: a block's requests all route to one shard, and an
/// eviction happens after the victim's last access and before its next
/// request, so `reused_later` of the victim's most recent request IS
/// "was it requested again after this eviction".
// Wall-clock exception: access latency is a Volatile (log-only) metric —
// see clippy.toml and rust/tests/lint_invariants.rs.
#[allow(clippy::disallowed_methods)]
pub fn replay_on_shards_observed(
    cache: &ShardedCache,
    trace: &[BlockRequest],
    features: &[FeatureVec],
    scores: &[Option<f32>],
    registry: &MetricsRegistry,
    cfg: ObsConfig,
) -> (Vec<ShardStats>, RunObservations) {
    let n = cache.n_shards();
    let partitions = partition_by_shard(trace, n);
    let scan_hist = registry.histogram("evict.scan_steps", MetricClass::Deterministic, n);
    let access_ns = registry.histogram("replay.access_ns", MetricClass::Volatile, n);
    let results = run_sharded(n, |w| {
        let mut windows = WindowSeries::new(cfg.window_us);
        let mut audit = EvictionAudit::new(cfg.audit_every, cfg.audit_cap);
        let mut last: IdHashMap<BlockId, usize> = IdHashMap::default();
        for &i in &partitions[w] {
            let req = &trace[i];
            let predicted_here = scores.get(i).copied().flatten().map(|s| s > 0.0);
            let ctx = AccessContext {
                time: req.time,
                size: req.size,
                kind: req.kind,
                file: req.block.0,
                file_width: 1,
                file_complete: false,
                affinity: req.affinity,
                predicted_reuse: predicted_here,
                recompute_cost: req.recompute_cost,
            };
            let t0 = access_ns.is_active().then(Instant::now);
            let outcome = cache.access_or_insert(req.block, &ctx);
            if let Some(t0) = t0 {
                access_ns.record(w, t0.elapsed().as_nanos() as u64);
            }
            if !outcome.hit {
                scan_hist.record(w, u64::from(outcome.scan_steps));
            }
            // This worker is shard w's only writer, so the lock-free
            // snapshot it reads back is its own deterministic state.
            let occupancy = cache.snapshot_of(w).blocks;
            let win = windows.at(req.time);
            win.requests += 1;
            win.hits += u64::from(outcome.hit);
            win.insertions += u64::from(outcome.inserted);
            win.occupancy_end = occupancy;
            for (victim, cause) in outcome.evicted.iter().zip(&outcome.causes) {
                match cause {
                    EvictCause::Capacity => win.evict_capacity += 1,
                    EvictCause::AdmissionDuel => win.evict_admission += 1,
                    EvictCause::CostTieBreak => win.evict_cost_tie += 1,
                }
                if let Some(li) = last.remove(victim) {
                    let actual = trace[li].reused_later;
                    let predicted = scores.get(li).copied().flatten().map(|s| s > 0.0);
                    match predicted {
                        Some(true) if actual => win.tp += 1,
                        Some(true) => win.fp += 1,
                        Some(false) if actual => win.fn_ += 1,
                        Some(false) => win.tn += 1,
                        None => {}
                    }
                    audit.observe(|| AuditEntry {
                        at: req.time,
                        block: *victim,
                        cause: *cause,
                        features: features.get(li).copied().unwrap_or_default(),
                        score: scores.get(li).copied().flatten().unwrap_or(0.0),
                        predicted,
                        actual,
                    });
                }
            }
            last.insert(req.block, i);
        }
        (cache.stats_of(w), windows.finish(), audit)
    });
    let mut per_shard = Vec::with_capacity(n);
    let mut window_parts = Vec::with_capacity(n);
    let mut audit_parts = Vec::with_capacity(n);
    for (stats, windows, audit) in results {
        per_shard.push(stats);
        window_parts.push(windows);
        audit_parts.push(audit);
    }
    let (audit, audit_seen) = merge_audits(audit_parts);
    (
        per_shard,
        RunObservations {
            windows: merge_series(window_parts),
            audit,
            audit_seen,
            audit_every: cfg.audit_every.max(1),
        },
    )
}

/// Full observed pipeline for one configuration: classify once (keeping
/// features + scores for the audit ring), replay with telemetry, report.
// disallowed_methods: replay wall time is reporting-only (Volatile class).
#[allow(clippy::too_many_arguments, clippy::disallowed_methods)]
pub fn run_observed(
    policy: &str,
    admission: &str,
    shards: usize,
    capacity: u64,
    trace: &[BlockRequest],
    kernel: KernelKind,
    batch: usize,
    registry: &MetricsRegistry,
    cfg: ObsConfig,
) -> Result<(ShardedReplayReport, RunObservations)> {
    let (features, scores) = classify_trace_scored(trace, kernel, batch)?;
    let cache = ShardedCache::from_registry_with_admission(policy, admission, shards, capacity)
        .with_context(|| format!("unknown policy {policy:?} or admission {admission:?}"))?;
    let t0 = Instant::now();
    let (per_shard, obs) =
        replay_on_shards_observed(&cache, trace, &features, &scores, registry, cfg);
    let wall = t0.elapsed();
    let mut stats = ShardStats::default();
    for s in &per_shard {
        stats.merge(s);
    }
    Ok((
        ShardedReplayReport {
            policy: policy.to_string(),
            admission: admission.to_string(),
            shards: cache.n_shards(),
            stats,
            per_shard,
            wall,
        },
        obs,
    ))
}

/// What concurrent lock-free stats readers observed during a replay (see
/// [`replay_with_stats_readers`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct StatsReaderReport {
    /// Concurrent reader threads that ran during the replay.
    pub readers: usize,
    /// Merged-stats snapshots taken across all readers while the shard
    /// workers were replaying.
    pub snapshots: u64,
    /// Snapshots that violated an internal-consistency invariant
    /// (`hits + misses == requests`, `used <= capacity`, per-shard
    /// coupling). Must be 0 — the seqlock guarantees it.
    pub inconsistencies: u64,
}

/// [`replay_on_shards`] with `n_readers` concurrent reader threads
/// hammering the lock-free stats path (`stats()`, `used()`,
/// `snapshot_of()`) for the whole duration of the replay. Readers check
/// every snapshot for internal consistency; with the seqlock stats block
/// they never serialize the shard workers (benchmarked in
/// `bench_sharded`'s reader-contention scenario).
pub fn replay_with_stats_readers(
    cache: &ShardedCache,
    trace: &[BlockRequest],
    classes: &[Option<bool>],
    n_readers: usize,
) -> (Vec<ShardStats>, StatsReaderReport) {
    if n_readers == 0 {
        return (replay_on_shards(cache, trace, classes), StatsReaderReport::default());
    }
    let n = cache.n_shards();
    let partitions = partition_by_shard(trace, n);
    let worker = |w: usize| {
        replay_slice(cache, trace, classes, &partitions[w]);
        cache.stats_of(w)
    };
    let monitor = |done: &AtomicBool| {
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..n_readers)
                .map(|_| {
                    scope.spawn(move || {
                        let mut snapshots = 0u64;
                        let mut inconsistencies = 0u64;
                        let mut last_requests = 0u64;
                        // do-while: at least one snapshot even when the
                        // replay finishes before the reader's first pass.
                        loop {
                            let merged = cache.stats();
                            let mut ok = merged.hits + merged.misses == merged.requests
                                && cache.used() <= cache.capacity()
                                && merged.requests >= last_requests;
                            last_requests = merged.requests;
                            for s in 0..n {
                                let snap = cache.snapshot_of(s);
                                ok &= snap.stats.hits + snap.stats.misses
                                    == snap.stats.requests;
                            }
                            snapshots += 1;
                            inconsistencies += u64::from(!ok);
                            // Acquire: pairs with the harness's Release
                            // store; the workers' final counters precede
                            // this last observation.
                            if done.load(Ordering::Acquire) {
                                break;
                            }
                            std::thread::yield_now();
                        }
                        (snapshots, inconsistencies)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("stats reader panicked"))
                .fold((0u64, 0u64), |acc, (s, i)| (acc.0 + s, acc.1 + i))
        })
    };
    let (per_shard, (snapshots, inconsistencies)) =
        run_sharded_with_monitor(n, worker, monitor);
    (
        per_shard,
        StatsReaderReport { readers: n_readers, snapshots, inconsistencies },
    )
}

/// Replay `trace` with precomputed predictions on a fresh `shards`-way
/// cache and report merged + per-shard stats with the replay wall time.
pub fn run_with_classes(
    policy: &str,
    shards: usize,
    capacity: u64,
    trace: &[BlockRequest],
    classes: &[Option<bool>],
) -> Result<ShardedReplayReport> {
    run_with_admission(policy, "always", shards, capacity, trace, classes)
}

/// Like [`run_with_classes`] but with an admission policy from
/// `cache::admission` in front of every shard (the `repro admission`
/// sweep path; `"always"` is exactly [`run_with_classes`]).
// disallowed_methods: replay wall time is reporting-only (Volatile class).
#[allow(clippy::disallowed_methods)]
pub fn run_with_admission(
    policy: &str,
    admission: &str,
    shards: usize,
    capacity: u64,
    trace: &[BlockRequest],
    classes: &[Option<bool>],
) -> Result<ShardedReplayReport> {
    let cache = ShardedCache::from_registry_with_admission(policy, admission, shards, capacity)
        .with_context(|| format!("unknown policy {policy:?} or admission {admission:?}"))?;
    let t0 = Instant::now();
    let per_shard = replay_on_shards(&cache, trace, classes);
    let wall = t0.elapsed();
    let mut stats = ShardStats::default();
    for s in &per_shard {
        stats.merge(s);
    }
    Ok(ShardedReplayReport {
        policy: policy.to_string(),
        admission: admission.to_string(),
        shards: cache.n_shards(),
        stats,
        per_shard,
        wall,
    })
}

/// Full pipeline for one shard count: classify once, then replay.
pub fn run(
    policy: &str,
    shards: usize,
    capacity: u64,
    trace: &[BlockRequest],
) -> Result<ShardedReplayReport> {
    let classes = classify_trace(trace, KernelKind::Rbf, 64)?;
    run_with_classes(policy, shards, capacity, trace, &classes)
}

/// Sweep several shard counts over the same trace. The classifier pass
/// runs once — predictions do not depend on the shard count — so the sweep
/// cost is dominated by the replays themselves.
pub fn run_sweep(
    policy: &str,
    shard_counts: &[usize],
    capacity: u64,
    trace: &[BlockRequest],
) -> Result<Vec<ShardedReplayReport>> {
    let classes = classify_trace(trace, KernelKind::Rbf, 64)?;
    shard_counts
        .iter()
        .map(|&n| run_with_classes(policy, n, capacity, trace, &classes))
        .collect()
}

/// Render a shard-count sweep as a table (the `repro sharded` output).
pub fn render(reports: &[ShardedReplayReport]) -> Table {
    let mut t = Table::new(vec![
        "policy",
        "shards",
        "hit ratio",
        "evictions",
        "replay wall (ms)",
        "req/s",
    ]);
    for r in reports {
        t.add_row(vec![
            r.policy.clone(),
            r.shards.to_string(),
            fmt_f(r.hit_ratio(), 4),
            r.stats.evictions.to_string(),
            fmt_f(r.wall.as_secs_f64() * 1e3, 2),
            format!("{:.0}", r.requests_per_sec()),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::DEFAULT_AUDIT_EVERY;
    use crate::util::bytes::MB;
    use crate::workload::fig3_trace;

    #[test]
    fn classifier_pass_labels_every_request() {
        let trace = fig3_trace(64 * MB, 3);
        let classes = classify_trace(&trace, KernelKind::Rbf, 64).unwrap();
        assert_eq!(classes.len(), trace.len());
        assert!(classes.iter().any(|c| c.is_some()), "mixed trace must train");
        // Both classes must be predicted somewhere on the pollution trace.
        assert!(classes.iter().any(|c| *c == Some(true)));
        assert!(classes.iter().any(|c| *c == Some(false)));
    }

    #[test]
    fn one_shard_replay_matches_sequential_replay() {
        let trace = fig3_trace(64 * MB, 5);
        let classes = classify_trace(&trace, KernelKind::Rbf, 64).unwrap();
        // Sequential ground truth.
        let seq = ShardedCache::from_registry("h-svm-lru", 1, 8 * 64 * MB).unwrap();
        for (i, req) in trace.iter().enumerate() {
            let ctx = AccessContext {
                time: req.time,
                size: req.size,
                kind: req.kind,
                file: req.block.0,
                file_width: 1,
                file_complete: false,
                affinity: req.affinity,
                predicted_reuse: classes[i],
                recompute_cost: req.recompute_cost,
            };
            seq.access_or_insert(req.block, &ctx);
        }
        let report = run("h-svm-lru", 1, 8 * 64 * MB, &trace).unwrap();
        assert_eq!(report.stats, seq.stats());
        assert_eq!(report.per_shard.len(), 1);
    }

    #[test]
    fn multi_shard_sweep_counts_every_request() {
        let trace = fig3_trace(64 * MB, 7);
        // 16 blocks of capacity: at 8 shards every shard still holds 2
        // blocks, enough for the Zipf-hot inputs to produce hits.
        let reports = run_sweep("lru", &[2, 4, 8], 16 * 64 * MB, &trace).unwrap();
        assert_eq!(reports.len(), 3);
        for (report, &shards) in reports.iter().zip(&[2usize, 4, 8]) {
            assert_eq!(report.shards, shards);
            assert_eq!(report.stats.requests, trace.len() as u64);
            assert_eq!(
                report.stats.hits + report.stats.misses,
                report.stats.requests
            );
            assert!(report.per_shard.iter().all(|s| s.requests > 0));
            assert!(report.stats.hit_ratio() > 0.0);
        }
    }

    #[test]
    fn unknown_policy_errors() {
        let trace = fig3_trace(64 * MB, 3);
        assert!(run("nonsense", 2, 8 * 64 * MB, &trace).is_err());
    }

    #[test]
    fn observed_replay_matches_plain_replay_and_its_own_windows() {
        let trace = fig3_trace(64 * MB, 11);
        let registry = MetricsRegistry::new();
        let (report, obs) = run_observed(
            "h-svm-lru",
            "always",
            4,
            8 * 64 * MB,
            &trace,
            KernelKind::Rbf,
            64,
            &registry,
            ObsConfig::default(),
        )
        .unwrap();
        // Observation must not perturb the cache: same stats as the
        // plain path on the same trace/policy/predictions.
        let classes = classify_trace(&trace, KernelKind::Rbf, 64).unwrap();
        let plain = run_with_classes("h-svm-lru", 4, 8 * 64 * MB, &trace, &classes).unwrap();
        assert_eq!(report.stats, plain.stats);
        assert_eq!(report.per_shard, plain.per_shard);

        // Window sums reproduce the merged counters.
        let requests: u64 = obs.windows.iter().map(|(_, w)| w.requests).sum();
        let hits: u64 = obs.windows.iter().map(|(_, w)| w.hits).sum();
        let evictions: u64 = obs.windows.iter().map(|(_, w)| w.evictions()).sum();
        assert_eq!(requests, report.stats.requests);
        assert_eq!(hits, report.stats.hits);
        assert_eq!(evictions, report.stats.evictions);
        // Confusion counts only cover evictions whose victim was seen
        // before (all of them here) and carried a prediction.
        let labeled: u64 = obs.windows.iter().map(|(_, w)| w.labeled_evictions()).sum();
        assert!(labeled <= evictions);
        assert!(labeled > 0, "classified trace must label some evictions");

        // Audit ring: sampled every Nth eviction, each entry labeled.
        assert_eq!(obs.audit_every, DEFAULT_AUDIT_EVERY);
        assert!(obs.audit_seen > 0);
        // Each of the 4 worker rings samples ceil(seen_w / every) entries,
        // so the merged total may exceed the global ceiling by one per ring.
        assert!(obs.audit.len() as u64 <= obs.audit_seen / obs.audit_every + 4);
        assert!(!obs.audit.is_empty());
        assert!(obs.audit.windows(2).all(|p| (p[0].at, p[0].block.0)
            <= (p[1].at, p[1].block.0)));

        // The registry picked up the deterministic scan-work histogram.
        let hists = registry.hist_snapshots();
        let scan = hists
            .iter()
            .find(|(name, _, _)| name == "evict.scan_steps")
            .expect("scan histogram registered");
        assert_eq!(scan.1, MetricClass::Deterministic);
        assert_eq!(scan.2.count, report.stats.misses);
    }

    #[test]
    fn observed_replay_with_disabled_registry_still_windows() {
        let trace = fig3_trace(64 * MB, 4);
        let registry = MetricsRegistry::disabled();
        let (report, obs) = run_observed(
            "lru",
            "always",
            2,
            8 * 64 * MB,
            &trace,
            KernelKind::Rbf,
            64,
            &registry,
            ObsConfig { window_us: 500_000, audit_every: 1, audit_cap: 16 },
        )
        .unwrap();
        let requests: u64 = obs.windows.iter().map(|(_, w)| w.requests).sum();
        assert_eq!(requests, report.stats.requests);
        assert!(registry.hist_snapshots().is_empty(), "disabled registry records nothing");
        assert!(obs.audit.len() <= 2 * 16, "per-worker audit ring capacity bound");
    }

    #[test]
    fn stats_readers_see_only_consistent_snapshots() {
        let trace = fig3_trace(64 * MB, 9);
        let cache = ShardedCache::from_registry("lru", 4, 8 * 64 * MB).unwrap();
        let (per_shard, report) = replay_with_stats_readers(&cache, &trace, &[], 2);
        assert_eq!(report.readers, 2);
        assert!(report.snapshots > 0, "readers must have observed the replay");
        assert_eq!(report.inconsistencies, 0, "seqlock snapshots must be consistent");
        let mut merged = ShardStats::default();
        for s in &per_shard {
            merged.merge(s);
        }
        assert_eq!(merged, cache.stats());
        assert_eq!(merged.requests, trace.len() as u64);
        // Reader-free path is the plain replay.
        let cache2 = ShardedCache::from_registry("lru", 4, 8 * 64 * MB).unwrap();
        let (plain, none) = replay_with_stats_readers(&cache2, &trace, &[], 0);
        assert_eq!(none.readers, 0);
        assert_eq!(none.snapshots, 0);
        assert_eq!(plain, per_shard, "readers must not perturb the replay");
    }
}
