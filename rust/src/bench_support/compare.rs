//! Bench-regression gate: parse the records `bench_support::write_json`
//! emits and fail when a tracked metric regresses beyond a tolerance.
//!
//! CI runs the `--quick --json` bench drivers, then `repro bench-gate
//! --baseline BENCH_baseline --current rust` compares the fresh JSONs
//! against the committed baselines. Uploading artifacts alone is not a
//! regression gate — this module is what actually *fails the build*.
//!
//! Design choices:
//!
//! * **`min_ns` is the tracked metric.** On shared CI runners the mean is
//!   polluted by scheduler noise; the minimum over the measured
//!   iterations is the closest observable to the true cost of the code.
//! * **Names are matched canonically.** `Bencher::run_per_op` appends a
//!   measured `" [123 ns/op]"` annotation to the result name, which
//!   differs run to run; [`canonical_name`] strips it on both sides.
//! * **A missing tracked metric fails the gate.** Renaming or deleting a
//!   bench silently would un-watch it; the gate reports it as missing and
//!   fails, forcing the baseline to be updated deliberately.
//!
//! The JSON parser below handles exactly the subset our own writer emits
//! (objects, arrays, strings with escapes, unsigned integers) — there is
//! no serde in the offline dependency set.

use std::path::Path;

use anyhow::{bail, Context, Result};

/// One parsed bench record (a row of `BENCH_*.json`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BenchRecord {
    pub name: String,
    pub iterations: u64,
    pub mean_ns: u64,
    pub std_dev_ns: u64,
    pub min_ns: u64,
    pub p50_ns: u64,
    pub p95_ns: u64,
}

/// One parsed bench document (`{"suite":…,"results":[…]}`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BenchDoc {
    pub suite: String,
    pub results: Vec<BenchRecord>,
}

/// Strip the run-dependent `" [123 ns/op]"` annotation `run_per_op`
/// appends, so baseline and current rows match by stable name.
pub fn canonical_name(name: &str) -> &str {
    match name.find(" [") {
        Some(i) => &name[..i],
        None => name,
    }
    .trim_end()
}

// ------------------------------------------------------------ JSON subset

#[derive(Debug, Clone, PartialEq)]
enum Json {
    Str(String),
    Num(u64),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    fn as_num(&self) -> Option<u64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn new(s: &'a str) -> Self {
        Parser { bytes: s.as_bytes(), pos: 0 }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&mut self) -> Result<u8> {
        self.skip_ws();
        self.bytes
            .get(self.pos)
            .copied()
            .context("unexpected end of bench JSON")
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.peek()?;
        if got != b {
            bail!(
                "bench JSON: expected {:?} at byte {}, got {:?}",
                b as char,
                self.pos,
                got as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'0'..=b'9' => self.number(),
            other => bail!("bench JSON: unexpected {:?} at byte {}", other as char, self.pos),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(&b) = self.bytes.get(self.pos) else {
                bail!("bench JSON: unterminated string");
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(&esc) = self.bytes.get(self.pos) else {
                        bail!("bench JSON: dangling escape");
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .context("bench JSON: short \\u escape")?;
                            self.pos += 4;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).context("bad \\u escape")?,
                                16,
                            )
                            .context("bad \\u escape")?;
                            out.push(
                                char::from_u32(code).context("bad \\u code point")?,
                            );
                        }
                        other => bail!("bench JSON: unknown escape \\{}", other as char),
                    }
                }
                _ if b < 0x80 => out.push(b as char),
                _ => {
                    // Multi-byte UTF-8 sequence: copy it through whole.
                    let len = if b >= 0xF0 {
                        4
                    } else if b >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    let seq = self
                        .bytes
                        .get(start..start + len)
                        .context("bench JSON: truncated UTF-8 sequence")?;
                    out.push_str(
                        std::str::from_utf8(seq).context("bench JSON: bad UTF-8 in string")?,
                    );
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self
            .bytes
            .get(self.pos)
            .is_some_and(|b| b.is_ascii_digit())
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii digits");
        // Durations can exceed u64 only after ~585 years; clamp instead of
        // failing so a pathological record still parses.
        let n = text.parse::<u128>().context("bench JSON: bad number")?;
        Ok(Json::Num(n.min(u64::MAX as u128) as u64))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("bench JSON: expected , or ] got {:?}", other as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.expect(b':')?;
            fields.push((key, self.value()?));
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                other => bail!("bench JSON: expected , or }} got {:?}", other as char),
            }
        }
    }
}

/// Parse one `BENCH_*.json` document.
pub fn parse_doc(json: &str) -> Result<BenchDoc> {
    let root = Parser::new(json).value()?;
    let suite = root
        .get("suite")
        .and_then(Json::as_str)
        .context("bench JSON: missing suite")?
        .to_string();
    let rows = match root.get("results").context("bench JSON: missing results")? {
        Json::Arr(rows) => rows,
        _ => bail!("bench JSON: results is not an array"),
    };
    let num = |row: &Json, key: &str| -> Result<u64> {
        row.get(key)
            .and_then(Json::as_num)
            .with_context(|| format!("bench JSON: missing numeric {key:?}"))
    };
    let mut results = Vec::with_capacity(rows.len());
    for row in rows {
        results.push(BenchRecord {
            name: row
                .get("name")
                .and_then(Json::as_str)
                .context("bench JSON: missing result name")?
                .to_string(),
            iterations: num(row, "iterations")?,
            mean_ns: num(row, "mean_ns")?,
            std_dev_ns: num(row, "std_dev_ns")?,
            min_ns: num(row, "min_ns")?,
            p50_ns: num(row, "p50_ns")?,
            p95_ns: num(row, "p95_ns")?,
        });
    }
    Ok(BenchDoc { suite, results })
}

// --------------------------------------------------------------- the gate

/// One tracked metric that got slower than the baseline allows.
#[derive(Debug, Clone, PartialEq)]
pub struct Regression {
    pub name: String,
    pub baseline_ns: u64,
    pub current_ns: u64,
    /// current / baseline (> 1 + tolerance by construction).
    pub ratio: f64,
}

/// Outcome of comparing one current document against its baseline.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GateReport {
    pub suite: String,
    /// Metrics present on both sides and compared.
    pub compared: usize,
    /// Tracked metrics beyond tolerance — any entry fails the gate.
    pub regressions: Vec<Regression>,
    /// Baseline metrics absent from the current run — also a failure
    /// (a bench silently disappeared or was renamed).
    pub missing: Vec<String>,
    /// Current metrics with no baseline yet (informational: new benches).
    pub added: usize,
}

impl GateReport {
    pub fn passed(&self) -> bool {
        self.regressions.is_empty() && self.missing.is_empty()
    }
}

/// Compare `current` against `baseline` on `min_ns`, flagging anything
/// slower than `baseline * (1 + tolerance)`.
pub fn compare(baseline: &BenchDoc, current: &BenchDoc, tolerance: f64) -> GateReport {
    let mut report = GateReport { suite: baseline.suite.clone(), ..GateReport::default() };
    let current_by_name: Vec<(&str, &BenchRecord)> = current
        .results
        .iter()
        .map(|r| (canonical_name(&r.name), r))
        .collect();
    for base in &baseline.results {
        let name = canonical_name(&base.name);
        let Some((_, cur)) = current_by_name.iter().find(|(n, _)| *n == name) else {
            report.missing.push(name.to_string());
            continue;
        };
        report.compared += 1;
        let limit = base.min_ns as f64 * (1.0 + tolerance);
        if (cur.min_ns as f64) > limit {
            report.regressions.push(Regression {
                name: name.to_string(),
                baseline_ns: base.min_ns,
                current_ns: cur.min_ns,
                ratio: cur.min_ns as f64 / (base.min_ns as f64).max(1.0),
            });
        }
    }
    report.added = current
        .results
        .iter()
        .filter(|r| {
            let name = canonical_name(&r.name);
            !baseline
                .results
                .iter()
                .any(|b| canonical_name(&b.name) == name)
        })
        .count();
    report
}

/// Load + compare one suite's baseline and current record files.
pub fn gate_files(baseline: &Path, current: &Path, tolerance: f64) -> Result<GateReport> {
    let base = std::fs::read_to_string(baseline)
        .with_context(|| format!("reading baseline {baseline:?}"))?;
    let cur = std::fs::read_to_string(current)
        .with_context(|| format!("reading current record {current:?}"))?;
    let base = parse_doc(&base).with_context(|| format!("parsing {baseline:?}"))?;
    let cur = parse_doc(&cur).with_context(|| format!("parsing {current:?}"))?;
    Ok(compare(&base, &cur, tolerance))
}

/// Render a gate report as the lines the CI log shows.
pub fn render_report(report: &GateReport, tolerance: f64) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "suite {:<10} {} metrics compared, {} new, tolerance {:.0}%\n",
        report.suite,
        report.compared,
        report.added,
        tolerance * 100.0
    ));
    for m in &report.missing {
        out.push_str(&format!("  MISSING    {m} (tracked metric disappeared)\n"));
    }
    for r in &report.regressions {
        out.push_str(&format!(
            "  REGRESSED  {}: {} ns -> {} ns ({:+.1}%)\n",
            r.name,
            r.baseline_ns,
            r.current_ns,
            (r.ratio - 1.0) * 100.0
        ));
    }
    if report.passed() {
        out.push_str("  ok\n");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_support::{results_to_json, BenchResult};
    use std::time::Duration;

    fn record(name: &str, min_ns: u64) -> BenchResult {
        BenchResult {
            name: name.to_string(),
            iterations: 5,
            mean: Duration::from_nanos(min_ns + 50),
            std_dev: Duration::from_nanos(3),
            min: Duration::from_nanos(min_ns),
            p50: Duration::from_nanos(min_ns + 40),
            p95: Duration::from_nanos(min_ns + 90),
        }
    }

    fn doc(suite: &str, rows: &[(&str, u64)]) -> BenchDoc {
        let results: Vec<BenchResult> =
            rows.iter().map(|(n, ns)| record(n, *ns)).collect();
        parse_doc(&results_to_json(suite, &results)).unwrap()
    }

    #[test]
    fn round_trips_the_writers_output() {
        let json = results_to_json("online", &[record("a \"quoted\"\nname", 123)]);
        let doc = parse_doc(&json).unwrap();
        assert_eq!(doc.suite, "online");
        assert_eq!(doc.results.len(), 1);
        assert_eq!(doc.results[0].name, "a \"quoted\"\nname");
        assert_eq!(doc.results[0].min_ns, 123);
        assert_eq!(doc.results[0].mean_ns, 173);
        assert_eq!(doc.results[0].iterations, 5);
        // Non-ASCII passes through the writer raw; the parser must copy
        // the sequence whole, not byte-by-byte.
        let json = results_to_json("s", &[record("latency in µs — fast", 9)]);
        let doc = parse_doc(&json).unwrap();
        assert_eq!(doc.results[0].name, "latency in µs — fast");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_doc("").is_err());
        assert!(parse_doc("{").is_err());
        assert!(parse_doc("{\"suite\":\"x\"}").is_err(), "results required");
        assert!(parse_doc("{\"suite\":3,\"results\":[]}").is_err());
        assert!(
            parse_doc("{\"suite\":\"x\",\"results\":[{\"name\":\"a\"}]}").is_err(),
            "metrics required"
        );
    }

    #[test]
    fn canonical_name_strips_per_op_annotation() {
        assert_eq!(canonical_name("lru access mix [123 ns/op]"), "lru access mix");
        assert_eq!(canonical_name("plain name"), "plain name");
        assert_eq!(canonical_name("trailing  "), "trailing");
    }

    #[test]
    fn within_tolerance_passes() {
        let base = doc("s", &[("a", 100), ("b", 200)]);
        let cur = doc("s", &[("a", 110), ("b", 190)]);
        let report = compare(&base, &cur, 0.15);
        assert!(report.passed(), "{report:?}");
        assert_eq!(report.compared, 2);
        assert!(report.regressions.is_empty());
    }

    /// The acceptance check: an injected regression must fail the gate.
    #[test]
    fn injected_regression_fails_the_gate() {
        let base = doc("s", &[("a", 100), ("b", 200)]);
        let cur = doc("s", &[("a", 100), ("b", 260)]); // +30% on b
        let report = compare(&base, &cur, 0.15);
        assert!(!report.passed());
        assert_eq!(report.regressions.len(), 1);
        let r = &report.regressions[0];
        assert_eq!(r.name, "b");
        assert_eq!(r.baseline_ns, 200);
        assert_eq!(r.current_ns, 260);
        assert!((r.ratio - 1.3).abs() < 1e-9);
        let rendered = render_report(&report, 0.15);
        assert!(rendered.contains("REGRESSED"), "{rendered}");
        assert!(rendered.contains("b: 200 ns -> 260 ns"), "{rendered}");
    }

    #[test]
    fn per_op_annotations_match_across_runs() {
        // run_per_op stamps a measured ns/op into the name: two runs carry
        // different annotations but must still be the same tracked metric.
        let base = doc("s", &[("lru mix [101 ns/op]", 100)]);
        let cur = doc("s", &[("lru mix [240 ns/op]", 240)]);
        let report = compare(&base, &cur, 0.15);
        assert_eq!(report.compared, 1);
        assert_eq!(report.regressions.len(), 1);
        assert_eq!(report.regressions[0].name, "lru mix");
    }

    #[test]
    fn missing_tracked_metric_fails() {
        let base = doc("s", &[("a", 100), ("gone", 50)]);
        let cur = doc("s", &[("a", 100), ("brand new", 70)]);
        let report = compare(&base, &cur, 0.15);
        assert!(!report.passed());
        assert_eq!(report.missing, vec!["gone".to_string()]);
        assert_eq!(report.added, 1, "new benches are informational");
        assert!(render_report(&report, 0.15).contains("MISSING"));
    }

    #[test]
    fn gate_files_end_to_end() {
        let dir = std::env::temp_dir().join("hsvmlru_gate_test");
        std::fs::create_dir_all(&dir).unwrap();
        let base_path = dir.join("BENCH_x_base.json");
        let cur_path = dir.join("BENCH_x_cur.json");
        std::fs::write(&base_path, results_to_json("x", &[record("m", 100)])).unwrap();
        std::fs::write(&cur_path, results_to_json("x", &[record("m", 300)])).unwrap();
        let report = gate_files(&base_path, &cur_path, 0.15).unwrap();
        assert!(!report.passed(), "3x slowdown must fail");
        assert!(gate_files(&base_path, &base_path, 0.15).unwrap().passed());
        assert!(gate_files(Path::new("/definitely/missing"), &cur_path, 0.15).is_err());
    }
}
