//! In-crate benchmark harness (the offline cache has no `criterion`).
//!
//! `cargo bench` runs each `rust/benches/*.rs` binary with `harness =
//! false`; they use this module for warmup + repeated timing, robust
//! statistics and aligned reporting. End-to-end benches (one per paper
//! table/figure) print the paper-style rows next to the wall-clock cost of
//! regenerating them; micro benches report ns/op.

pub mod compare;

use std::time::{Duration, Instant};

use crate::util::stats::{Summary, Welford};

/// One benchmark measurement series.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iterations: u64,
    pub mean: Duration,
    pub std_dev: Duration,
    pub min: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12?}  p50 {:>12?}  p95 {:>12?}  min {:>12?}  (±{:?})",
            self.name, self.iterations, self.mean, self.p50, self.p95, self.min, self.std_dev
        )
    }

    /// One machine-readable JSON object (hand-rolled — no serde offline).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"name\":\"{}\",\"iterations\":{},\"mean_ns\":{},\"std_dev_ns\":{},\
             \"min_ns\":{},\"p50_ns\":{},\"p95_ns\":{}}}",
            json_escape(&self.name),
            self.iterations,
            self.mean.as_nanos(),
            self.std_dev.as_nanos(),
            self.min.as_nanos(),
            self.p50.as_nanos(),
            self.p95.as_nanos(),
        )
    }
}

fn json_escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            '\n' => "\\n".chars().collect(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

/// Serialize a bench run as one JSON document (`{"suite":…,"results":[…]}`).
pub fn results_to_json(suite: &str, results: &[BenchResult]) -> String {
    let rows: Vec<String> = results.iter().map(BenchResult::to_json).collect();
    format!(
        "{{\"suite\":\"{}\",\"results\":[{}]}}\n",
        json_escape(suite),
        rows.join(",")
    )
}

/// Write the machine-readable bench record (the `--json` flag of the
/// bench drivers) so the perf trajectory is tracked in CI artifacts.
pub fn write_json(path: &str, suite: &str, results: &[BenchResult]) -> std::io::Result<()> {
    std::fs::write(path, results_to_json(suite, results))
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Bencher {
    /// Warmup iterations (not recorded).
    pub warmup: u32,
    /// Measured iterations.
    pub iterations: u32,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 2, iterations: 10 }
    }
}

impl Bencher {
    pub fn new(warmup: u32, iterations: u32) -> Self {
        assert!(iterations > 0);
        Bencher { warmup, iterations }
    }

    /// Fast harness for micro benches: many iterations, batched timing.
    pub fn micro() -> Self {
        Bencher { warmup: 3, iterations: 30 }
    }

    /// Time `f` (called once per iteration).
    // Wall-clock exception: timing is this harness's whole job; bench
    // output is never part of the deterministic export — see clippy.toml
    // and rust/tests/lint_invariants.rs.
    #[allow(clippy::disallowed_methods)]
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut w = Welford::new();
        let mut samples = Vec::with_capacity(self.iterations as usize);
        for _ in 0..self.iterations {
            let t0 = Instant::now();
            f();
            let dt = t0.elapsed();
            w.push(dt.as_secs_f64());
            samples.push(dt.as_secs_f64());
        }
        // One sort serves min, p50 and p95 (util::stats::Summary) — the
        // free `percentile` clones and re-sorts per call.
        let summary = Summary::of(&samples);
        BenchResult {
            name: name.to_string(),
            iterations: self.iterations as u64,
            mean: Duration::from_secs_f64(w.mean()),
            std_dev: Duration::from_secs_f64(w.std_dev()),
            min: Duration::from_secs_f64(summary.min()),
            p50: Duration::from_secs_f64(summary.percentile(50.0)),
            p95: Duration::from_secs_f64(summary.percentile(95.0)),
        }
    }

    /// Time `f` where each call performs `ops` homogeneous operations;
    /// reports per-op latency in the result name.
    pub fn run_per_op<F: FnMut()>(&self, name: &str, ops: u64, mut f: F) -> BenchResult {
        let res = self.run(name, &mut f);
        let per_op = res.mean.as_nanos() as f64 / ops as f64;
        BenchResult { name: format!("{name} [{per_op:.0} ns/op]"), ..res }
    }
}

/// Standard bench banner.
pub fn banner(title: &str) {
    println!("\n================================================================");
    println!("bench: {title}");
    println!("================================================================");
}

/// `black_box` without nightly: defeat the optimizer via a volatile read.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleepless_work() {
        let b = Bencher::new(1, 5);
        let mut acc = 0u64;
        let r = b.run("sum", || {
            acc = black_box((0..10_000u64).sum());
        });
        assert_eq!(r.iterations, 5);
        assert!(r.mean.as_nanos() > 0);
        assert!(r.min <= r.mean);
        assert!(r.p50 <= r.p95);
        assert!(r.report().contains("sum"));
    }

    #[test]
    fn per_op_annotation() {
        let b = Bencher::new(0, 3);
        let r = b.run_per_op("op", 1000, || {
            black_box((0..1000u64).product::<u64>());
        });
        assert!(r.name.contains("ns/op"));
    }

    #[test]
    fn json_emission_is_well_formed() {
        let b = Bencher::new(0, 2);
        let r = b.run("a \"quoted\" name", || {
            black_box((0..100u64).sum::<u64>());
        });
        let doc = results_to_json("online", &[r.clone(), r]);
        assert!(doc.starts_with("{\"suite\":\"online\",\"results\":["));
        assert!(doc.trim_end().ends_with("]}"));
        assert!(doc.contains("\\\"quoted\\\""), "quotes escaped: {doc}");
        assert!(doc.contains("\"mean_ns\":"));
        assert_eq!(doc.matches("\"iterations\":2").count(), 2);
    }
}
