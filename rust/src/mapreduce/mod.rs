//! Simulated MapReduce engine: jobs, tasks, the wave scheduler and the
//! job-history server the SVM trains from.
//!
//! * `job` / `task` — specs and the Table 3/4 state machines.
//! * `scheduler` — wave-based slot scheduling with data-local placement;
//!   block reads flow through a pluggable `BlockService` (the cache
//!   coordinator at runtime).
//! * `history` — Table 3 records + lifecycle snapshots for SVM labeling.

pub mod history;
pub mod job;
pub mod scheduler;
pub mod task;

pub use history::{HistoryRecord, HistoryServer};
pub use job::{JobId, JobSpec, JobStatus};
pub use scheduler::{AccessRequest, BlockRead, BlockService, FailureModel, JobRun, Scheduler};
pub use task::{Task, TaskKind, TaskStatus};
