//! Jobs and their lifecycle states (the paper's Table 3/4 state machine).

use crate::cache::CacheAffinity;
use crate::hdfs::BlockId;

/// Job lifecycle states — "valid values of job state" from Table 3.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JobStatus {
    New,
    Initiated,
    Running,
    Succeeded,
    Failed,
    Killed,
    Error,
}

impl JobStatus {
    pub fn name(self) -> &'static str {
        match self {
            JobStatus::New => "new",
            JobStatus::Initiated => "initiated",
            JobStatus::Running => "running",
            JobStatus::Succeeded => "succeeded",
            JobStatus::Failed => "failed",
            JobStatus::Killed => "killed",
            JobStatus::Error => "error",
        }
    }

    pub fn is_terminal(self) -> bool {
        matches!(
            self,
            JobStatus::Succeeded | JobStatus::Failed | JobStatus::Killed | JobStatus::Error
        )
    }
}

/// A unique job id.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct JobId(pub u64);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job_{}", self.0)
    }
}

/// A runnable MapReduce job: one map task per input block, `n_reduces`
/// reduce tasks fed by the shuffle.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub id: JobId,
    /// Application name (WordCount, Sort, Grep, Join, Aggregation).
    pub app: String,
    pub affinity: CacheAffinity,
    /// Input blocks (map task inputs).
    pub input_blocks: Vec<BlockId>,
    pub n_reduces: usize,
    /// CPU seconds per MB of input for a map task.
    pub map_cpu_s_per_mb: f64,
    /// CPU seconds per MB of shuffled data for a reduce task.
    pub reduce_cpu_s_per_mb: f64,
    /// Intermediate-data volume as a fraction of input volume.
    pub shuffle_ratio: f64,
    /// For multi-stage apps (Join): number of chained MapReduce stages.
    pub stages: usize,
}

impl JobSpec {
    pub fn n_maps(&self) -> usize {
        self.input_blocks.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn terminal_states() {
        assert!(JobStatus::Succeeded.is_terminal());
        assert!(JobStatus::Failed.is_terminal());
        assert!(!JobStatus::Running.is_terminal());
        assert!(!JobStatus::New.is_terminal());
        assert_eq!(JobStatus::Initiated.name(), "initiated");
    }

    #[test]
    fn job_shape() {
        let job = JobSpec {
            id: JobId(1),
            app: "WordCount".into(),
            affinity: CacheAffinity::Medium,
            input_blocks: vec![BlockId(0), BlockId(1), BlockId(2)],
            n_reduces: 2,
            map_cpu_s_per_mb: 0.01,
            reduce_cpu_s_per_mb: 0.005,
            shuffle_ratio: 0.4,
            stages: 1,
        };
        assert_eq!(job.n_maps(), 3);
        assert_eq!(job.id.to_string(), "job_1");
    }
}
