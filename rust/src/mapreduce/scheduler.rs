//! Wave-based MapReduce scheduler over the simulated cluster.
//!
//! Jobs are decomposed into map tasks (one per input block) and reduce
//! tasks. Map slots and reduce slots per node come from the container
//! memory configuration (Table 6). Concurrent jobs share the slot pool in
//! round-robin order — the paper's fig 5/6 workloads assume "an equal share
//! of cluster resources" for the four applications of a workload.
//!
//! Placement is data-local with a bounded locality delay (HDFS-style): a
//! task prefers the replica/cached node unless a remote slot frees much
//! earlier. Block reads go through a pluggable `BlockService` — the cache
//! coordinator on the request path, or a no-cache stub for the H-NoCache
//! baseline.

use std::collections::VecDeque;

use crate::cache::CacheAffinity;
use crate::config::ClusterConfig;
use crate::hdfs::{BlockId, BlockKind, DataNodeId, ReadSource};
use crate::sim::{SimDuration, SimTime};
use crate::util::bytes::MB;

use super::job::{JobId, JobSpec, JobStatus};
use super::task::{Task, TaskKind, TaskStatus};

/// What a task tells the block service about itself (feature context).
#[derive(Debug, Clone)]
pub struct AccessRequest {
    pub app: String,
    pub affinity: CacheAffinity,
    pub kind: BlockKind,
    pub file: u64,
    pub file_width: u32,
    pub file_complete: bool,
}

/// Result of a block read issued through the service.
#[derive(Debug, Clone, Copy)]
pub struct BlockRead {
    /// Absolute completion time (includes queueing on node resources).
    pub completion: SimTime,
    pub source: ReadSource,
}

/// The request-path interface between the scheduler and the cache layer.
pub trait BlockService {
    /// Read `block` from `reader`'s perspective starting at `now`.
    fn read_block(
        &mut self,
        block: BlockId,
        reader: DataNodeId,
        now: SimTime,
        req: &AccessRequest,
    ) -> BlockRead;

    /// Which node can serve the block fastest right now (placement hint).
    fn preferred_node(&self, block: BlockId) -> Option<DataNodeId>;

    /// Replica nodes of the block (for data-local placement).
    fn replica_nodes(&self, block: BlockId) -> Vec<DataNodeId>;

    /// Block size lookup.
    fn block_size(&self, block: BlockId) -> u64;

    /// Register a job's intermediate (shuffle) data of `bytes` total and
    /// return its blocks. Hadoop ≥ 2.3's in-memory cache "can cache both
    /// input and intermediate data" (paper §2) — intermediate blocks flow
    /// through the same cache and are the main cache-pollution source
    /// H-SVM-LRU targets (read once by reduces, never again). The no-cache
    /// baseline returns no blocks (shuffle stays off the cache path).
    fn register_intermediate(&mut self, _job: JobId, _bytes: u64) -> Vec<BlockId> {
        Vec::new()
    }
}

/// Completed-job record used by metrics and the history server.
#[derive(Debug, Clone)]
pub struct JobRun {
    pub spec: JobSpec,
    pub status: JobStatus,
    pub start: SimTime,
    pub finish: SimTime,
    pub tasks: Vec<Task>,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub bytes_from_cache: u64,
    pub bytes_from_disk: u64,
    /// Injected-failure telemetry (FailureModel).
    pub failed_attempts: u64,
    pub killed_attempts: u64,
}

impl JobRun {
    pub fn execution_time(&self) -> SimDuration {
        self.finish - self.start
    }

    pub fn maps_completed(&self) -> usize {
        self.tasks
            .iter()
            .filter(|t| t.kind == TaskKind::Map && t.status == TaskStatus::Succeeded)
            .count()
    }

    pub fn reduces_completed(&self) -> usize {
        self.tasks
            .iter()
            .filter(|t| t.kind == TaskKind::Reduce && t.status == TaskStatus::Succeeded)
            .count()
    }

    pub fn avg_map_time(&self) -> SimDuration {
        self.avg_task_time(TaskKind::Map)
    }

    pub fn avg_reduce_time(&self) -> SimDuration {
        self.avg_task_time(TaskKind::Reduce)
    }

    fn avg_task_time(&self, kind: TaskKind) -> SimDuration {
        let times: Vec<u64> = self
            .tasks
            .iter()
            .filter(|t| t.kind == kind)
            .filter_map(|t| t.duration().map(|d| d.micros()))
            .collect();
        if times.is_empty() {
            SimDuration::ZERO
        } else {
            SimDuration::from_micros(times.iter().sum::<u64>() / times.len() as u64)
        }
    }
}

/// One map/reduce slot on a node.
#[derive(Debug, Clone, Copy)]
struct Slot {
    node: DataNodeId,
    free_at: SimTime,
}

/// Slot pool with earliest-free queries.
#[derive(Debug)]
struct SlotPool {
    slots: Vec<Slot>,
}

impl SlotPool {
    fn new(cfg: &ClusterConfig, per_node: usize) -> Self {
        let mut slots = Vec::with_capacity(cfg.datanodes * per_node);
        for n in 0..cfg.datanodes {
            for _ in 0..per_node {
                slots.push(Slot { node: DataNodeId(n as u32), free_at: SimTime::ZERO });
            }
        }
        SlotPool { slots }
    }

    fn earliest(&self) -> (usize, Slot) {
        let (i, s) = self
            .slots
            .iter()
            .enumerate()
            .min_by_key(|(i, s)| (s.free_at, *i))
            .expect("empty slot pool");
        (i, *s)
    }

    /// Earliest slot on one of `nodes`; None when `nodes` is empty.
    fn earliest_on(&self, nodes: &[DataNodeId]) -> Option<(usize, Slot)> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| nodes.contains(&s.node))
            .min_by_key(|(i, s)| (s.free_at, *i))
            .map(|(i, s)| (i, *s))
    }

    fn occupy(&mut self, idx: usize, until: SimTime) {
        self.slots[idx].free_at = until;
    }
}

/// Failure-injection model. The paper's Table 4 labeling rules cover
/// failed and killed (speculative) tasks — rows 6-9 only fire when tasks
/// can actually fail, so the simulator injects failures per attempt.
#[derive(Debug, Clone)]
pub struct FailureModel {
    /// Probability a map attempt fails (input re-read required, row 6).
    pub map_fail_prob: f64,
    /// Probability a map attempt is killed for speculative re-execution
    /// (row 8: the killed task's input will be read again elsewhere).
    pub map_kill_prob: f64,
    /// Attempts per task before the job gives up (Hadoop default: 4).
    pub max_attempts: u32,
    pub seed: u64,
}

impl Default for FailureModel {
    fn default() -> Self {
        FailureModel { map_fail_prob: 0.0, map_kill_prob: 0.0, max_attempts: 4, seed: 0xFA11 }
    }
}

impl FailureModel {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn with_rates(map_fail_prob: f64, map_kill_prob: f64, seed: u64) -> Self {
        FailureModel { map_fail_prob, map_kill_prob, max_attempts: 4, seed }
    }

    pub fn enabled(&self) -> bool {
        self.map_fail_prob > 0.0 || self.map_kill_prob > 0.0
    }
}

/// Scheduler for a batch of concurrent jobs.
pub struct Scheduler<'a> {
    cfg: &'a ClusterConfig,
    /// Locality delay: how much later a local slot may free and still be
    /// preferred over a remote one.
    locality_delay: SimDuration,
    failures: FailureModel,
}

impl<'a> Scheduler<'a> {
    pub fn new(cfg: &'a ClusterConfig) -> Self {
        Scheduler {
            cfg,
            locality_delay: SimDuration::from_secs_f64(3.0),
            failures: FailureModel::none(),
        }
    }

    /// Enable failure injection (speculative execution stays off per
    /// Table 6; kills model externally-triggered re-execution).
    pub fn with_failures(mut self, failures: FailureModel) -> Self {
        self.failures = failures;
        self
    }

    /// Run `jobs` concurrently from `start`, sharing slots round-robin.
    /// Returns one `JobRun` per job (same order).
    pub fn run_jobs(
        &self,
        jobs: &[JobSpec],
        svc: &mut dyn BlockService,
        start: SimTime,
    ) -> Vec<JobRun> {
        let mut map_slots = SlotPool::new(self.cfg, self.cfg.map_slots_per_node());
        let mut reduce_slots = SlotPool::new(self.cfg, self.cfg.reduce_slots_per_node());

        struct JobState {
            spec: JobSpec,
            pending_maps: VecDeque<usize>,
            tasks: Vec<Task>,
            maps_done: usize,
            map_barrier: SimTime,
            hits: u64,
            misses: u64,
            bytes_cache: u64,
            bytes_disk: u64,
            attempts: Vec<u32>,
            failed_attempts: u64,
            killed_attempts: u64,
        }

        let mut failure_rng = crate::util::rng::Pcg64::new(self.failures.seed, 0xDEAD);

        let mut states: Vec<JobState> = jobs
            .iter()
            .map(|spec| {
                let mut tasks = Vec::with_capacity(spec.n_maps() + spec.n_reduces);
                for (i, &b) in spec.input_blocks.iter().enumerate() {
                    tasks.push(Task::map(spec.id, i, b));
                }
                for i in 0..spec.n_reduces {
                    tasks.push(Task::reduce(spec.id, i));
                }
                JobState {
                    pending_maps: (0..jobs_n_maps(spec)).collect(),
                    attempts: vec![0; spec.n_maps()],
                    spec: spec.clone(),
                    tasks,
                    maps_done: 0,
                    map_barrier: start,
                    hits: 0,
                    misses: 0,
                    bytes_cache: 0,
                    bytes_disk: 0,
                    failed_attempts: 0,
                    killed_attempts: 0,
                }
            })
            .collect();

        // ---- map phase: round-robin across jobs for fair sharing ----
        let mut remaining: usize = states.iter().map(|s| s.pending_maps.len()).sum();
        let mut cursor = 0usize;
        while remaining > 0 {
            // next job with pending maps
            while states[cursor % states.len()].pending_maps.is_empty() {
                cursor += 1;
            }
            let ji = cursor % states.len();
            cursor += 1;
            let task_idx = states[ji].pending_maps.pop_front().unwrap();
            remaining -= 1;

            let block = states[ji].tasks[task_idx].input.expect("map without input");
            let size = svc.block_size(block);

            // Placement: prefer the cached node, then a replica, with a
            // bounded locality delay against the globally earliest slot.
            let mut candidates: Vec<DataNodeId> = Vec::new();
            if let Some(n) = svc.preferred_node(block) {
                candidates.push(n);
            }
            for n in svc.replica_nodes(block) {
                if !candidates.contains(&n) {
                    candidates.push(n);
                }
            }
            let (global_idx, global_slot) = map_slots.earliest();
            let (slot_idx, slot) = match map_slots.earliest_on(&candidates) {
                Some((li, ls))
                    if ls.free_at <= global_slot.free_at + self.locality_delay =>
                {
                    (li, ls)
                }
                _ => (global_idx, global_slot),
            };

            let task_start = slot.free_at.max(start);
            let req = AccessRequest {
                app: states[ji].spec.app.clone(),
                affinity: states[ji].spec.affinity,
                kind: BlockKind::Input,
                file: block_file_hint(&states[ji].spec),
                file_width: states[ji].spec.n_maps() as u32,
                file_complete: states[ji].maps_done + 1 == states[ji].spec.n_maps(),
            };
            let read = svc.read_block(block, slot.node, task_start, &req);
            let cpu = SimDuration::from_secs_f64(
                size as f64 / MB as f64 * states[ji].spec.map_cpu_s_per_mb,
            );

            // Failure injection (Table 4 rows 6/8): a failed attempt dies
            // mid-compute (half the CPU burned); a killed attempt is
            // re-executed elsewhere. Both re-enqueue the task, re-reading
            // the input — exactly the cache-relevant behaviour.
            states[ji].attempts[task_idx] += 1;
            let attempt = states[ji].attempts[task_idx];
            let outcome = if self.failures.enabled()
                && attempt < self.failures.max_attempts
            {
                if failure_rng.gen_bool(self.failures.map_fail_prob) {
                    Some(TaskStatus::Failed)
                } else if failure_rng.gen_bool(self.failures.map_kill_prob) {
                    Some(TaskStatus::Killed)
                } else {
                    None
                }
            } else {
                None
            };

            if let Some(status) = outcome {
                let abort = read.completion
                    + SimDuration::from_micros(cpu.micros() / 2);
                map_slots.occupy(slot_idx, abort);
                let st = &mut states[ji];
                match status {
                    TaskStatus::Failed => st.failed_attempts += 1,
                    _ => st.killed_attempts += 1,
                }
                // The attempt still consumed I/O.
                if read.source.is_cache() {
                    st.hits += 1;
                    st.bytes_cache += size;
                } else {
                    st.misses += 1;
                    st.bytes_disk += size;
                }
                st.pending_maps.push_back(task_idx);
                remaining += 1;
                continue;
            }

            let finish = read.completion + cpu;
            map_slots.occupy(slot_idx, finish);

            let st = &mut states[ji];
            let t = &mut st.tasks[task_idx];
            t.status = TaskStatus::Succeeded;
            t.node = Some(slot.node);
            t.start = Some(task_start);
            t.finish = Some(finish);
            st.maps_done += 1;
            st.map_barrier = st.map_barrier.max(finish);
            if read.source.is_cache() {
                st.hits += 1;
                st.bytes_cache += size;
            } else {
                st.misses += 1;
                st.bytes_disk += size;
            }
        }

        // ---- shuffle + reduce phase (and extra stages for Join-likes) ----
        states
            .into_iter()
            .map(|mut st| {
                let spec = st.spec.clone();
                let total_input: u64 = spec
                    .input_blocks
                    .iter()
                    .map(|&b| svc.block_size(b))
                    .sum();
                let shuffle_bytes = (total_input as f64 * spec.shuffle_ratio) as u64;
                let per_reduce = shuffle_bytes / spec.n_reduces.max(1) as u64;

                // Intermediate data rides the cache path when the service
                // supports it (HDFS ≥ 2.3 caches intermediate data too).
                let inter_blocks = svc.register_intermediate(spec.id, shuffle_bytes);

                let mut job_end = st.map_barrier;
                let reduce_indices: Vec<usize> = st
                    .tasks
                    .iter()
                    .enumerate()
                    .filter(|(_, t)| t.kind == TaskKind::Reduce)
                    .map(|(i, _)| i)
                    .collect();
                for (r, idx) in reduce_indices.into_iter().enumerate() {
                    let (slot_idx, slot) = reduce_slots.earliest();
                    let rstart = slot.free_at.max(st.map_barrier);
                    let mut cursor = rstart;
                    if inter_blocks.is_empty() {
                        // Analytic shuffle: map outputs are read from the
                        // mappers' disks and cross the network — the same
                        // costs the cache-path shuffle pays on a miss.
                        let shuffle_s = self.cfg.disk.seek_latency_s
                            + per_reduce as f64 / self.cfg.disk.read_bandwidth_bps
                            + per_reduce as f64 / self.cfg.network.bandwidth_bps
                            + self.cfg.network.rtt_s * spec.n_maps().max(1) as f64;
                        cursor = cursor + SimDuration::from_secs_f64(shuffle_s);
                    } else {
                        // Shuffle through the cache: this reduce fetches its
                        // share of the intermediate blocks.
                        let req = AccessRequest {
                            app: spec.app.clone(),
                            affinity: spec.affinity,
                            kind: BlockKind::Intermediate,
                            file: u64::MAX - spec.id.0, // per-job shuffle file
                            file_width: spec.n_reduces as u32,
                            file_complete: false,
                        };
                        for b in inter_blocks
                            .iter()
                            .skip(r)
                            .step_by(spec.n_reduces.max(1))
                        {
                            let node = st.tasks[idx]
                                .node
                                .or_else(|| svc.preferred_node(*b))
                                .unwrap_or(crate::hdfs::DataNodeId(0));
                            let read = svc.read_block(*b, node, cursor, &req);
                            cursor = read.completion;
                        }
                    }
                    let cpu_s = per_reduce as f64 / MB as f64 * spec.reduce_cpu_s_per_mb;
                    // output write-back to HDFS (local disk, replication
                    // pipeline overlaps — first copy dominates)
                    let write_s = per_reduce as f64 / self.cfg.disk.read_bandwidth_bps;
                    let finish = cursor + SimDuration::from_secs_f64(cpu_s + write_s);
                    reduce_slots.occupy(slot_idx, finish);
                    let t = &mut st.tasks[idx];
                    t.status = TaskStatus::Succeeded;
                    t.node = Some(slot.node);
                    t.start = Some(rstart);
                    t.finish = Some(finish);
                    job_end = job_end.max(finish);
                }

                // Multi-stage applications (Join): each extra stage re-reads
                // the previous stage's output from disk — exactly why the
                // paper finds Join benefits least from input caching.
                for _ in 1..spec.stages {
                    let stage_bytes = shuffle_bytes.max(1);
                    let read_s = self.cfg.disk.seek_latency_s
                        + stage_bytes as f64 / self.cfg.disk.read_bandwidth_bps;
                    let cpu_s =
                        stage_bytes as f64 / MB as f64 * spec.map_cpu_s_per_mb;
                    let slots_total = self.cfg.datanodes * self.cfg.map_slots_per_node();
                    let parallel = slots_total.max(1) as f64;
                    job_end = job_end
                        + SimDuration::from_secs_f64((read_s + cpu_s) / parallel.min(4.0));
                }

                JobRun {
                    spec,
                    status: JobStatus::Succeeded,
                    start,
                    finish: job_end,
                    tasks: st.tasks,
                    cache_hits: st.hits,
                    cache_misses: st.misses,
                    bytes_from_cache: st.bytes_cache,
                    bytes_from_disk: st.bytes_disk,
                    failed_attempts: st.failed_attempts,
                    killed_attempts: st.killed_attempts,
                }
            })
            .collect()
    }
}

fn jobs_n_maps(spec: &JobSpec) -> usize {
    spec.n_maps()
}

/// Stable per-job file grouping hint for policy features: all input blocks
/// of a job belong to the same logical input file set.
fn block_file_hint(spec: &JobSpec) -> u64 {
    spec.input_blocks.first().map(|b| b.0).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdfs::reader;

    /// A no-cache service: every read is a local/remote disk read.
    pub struct NoCacheService {
        pub cfg: ClusterConfig,
        pub sizes: std::collections::HashMap<BlockId, u64>,
        pub replicas: std::collections::HashMap<BlockId, Vec<DataNodeId>>,
    }

    impl BlockService for NoCacheService {
        fn read_block(
            &mut self,
            block: BlockId,
            reader_node: DataNodeId,
            now: SimTime,
            _req: &AccessRequest,
        ) -> BlockRead {
            let nodes = &self.replicas[&block];
            let source = if nodes.contains(&reader_node) {
                ReadSource::DiskLocal
            } else {
                ReadSource::DiskRemote
            };
            let d = reader::service_time(&self.cfg, source, self.sizes[&block]);
            BlockRead { completion: now + d, source }
        }

        fn preferred_node(&self, block: BlockId) -> Option<DataNodeId> {
            self.replicas[&block].first().copied()
        }

        fn replica_nodes(&self, block: BlockId) -> Vec<DataNodeId> {
            self.replicas[&block].clone()
        }

        fn block_size(&self, block: BlockId) -> u64 {
            self.sizes[&block]
        }
    }

    fn setup(n_blocks: u64) -> (ClusterConfig, NoCacheService, JobSpec) {
        let cfg = ClusterConfig { datanodes: 3, replication: 2, ..Default::default() };
        let mut sizes = std::collections::HashMap::new();
        let mut replicas = std::collections::HashMap::new();
        for i in 0..n_blocks {
            sizes.insert(BlockId(i), 64 * MB);
            replicas.insert(
                BlockId(i),
                vec![DataNodeId((i % 3) as u32), DataNodeId(((i + 1) % 3) as u32)],
            );
        }
        let spec = JobSpec {
            id: JobId(0),
            app: "WordCount".into(),
            affinity: CacheAffinity::Medium,
            input_blocks: (0..n_blocks).map(BlockId).collect(),
            n_reduces: 2,
            map_cpu_s_per_mb: 0.02,
            reduce_cpu_s_per_mb: 0.01,
            shuffle_ratio: 0.3,
            stages: 1,
        };
        let svc = NoCacheService { cfg: cfg.clone(), sizes, replicas };
        (cfg, svc, spec)
    }

    #[test]
    fn job_completes_all_tasks() {
        let (cfg, mut svc, spec) = setup(12);
        let sched = Scheduler::new(&cfg);
        let runs = sched.run_jobs(&[spec], &mut svc, SimTime::ZERO);
        assert_eq!(runs.len(), 1);
        let run = &runs[0];
        assert_eq!(run.status, JobStatus::Succeeded);
        assert_eq!(run.maps_completed(), 12);
        assert_eq!(run.reduces_completed(), 2);
        assert!(run.finish > run.start);
        assert_eq!(run.cache_hits, 0, "no-cache service can't hit");
        assert_eq!(run.cache_misses, 12);
        assert!(run.avg_map_time() > SimDuration::ZERO);
        assert!(run.avg_reduce_time() > SimDuration::ZERO);
    }

    #[test]
    fn more_blocks_take_longer() {
        let (cfg, mut svc_small, small) = setup(6);
        let sched = Scheduler::new(&cfg);
        let t_small = sched.run_jobs(&[small], &mut svc_small, SimTime::ZERO)[0]
            .execution_time();
        let (_, mut svc_big, big) = setup(48);
        let t_big = sched.run_jobs(&[big], &mut svc_big, SimTime::ZERO)[0].execution_time();
        assert!(t_big > t_small, "{t_big} <= {t_small}");
    }

    #[test]
    fn concurrent_jobs_share_slots_fairly() {
        let (cfg, mut svc, spec) = setup(24);
        let mut spec_b = spec.clone();
        spec_b.id = JobId(1);
        let sched = Scheduler::new(&cfg);
        let runs = sched.run_jobs(&[spec, spec_b], &mut svc, SimTime::ZERO);
        // Fair round-robin: both jobs read the same blocks, finish close
        // together rather than strictly serialized.
        let t0 = runs[0].execution_time().as_secs_f64();
        let t1 = runs[1].execution_time().as_secs_f64();
        assert!((t0 - t1).abs() / t0.max(t1) < 0.5, "t0={t0} t1={t1}");
    }

    #[test]
    fn multi_stage_jobs_take_longer() {
        let (cfg, mut svc, mut spec) = setup(12);
        let sched = Scheduler::new(&cfg);
        let single = sched.run_jobs(&[spec.clone()], &mut svc, SimTime::ZERO)[0]
            .execution_time();
        spec.stages = 3;
        let (_, mut svc2, _) = setup(12);
        let multi = sched.run_jobs(&[spec], &mut svc2, SimTime::ZERO)[0].execution_time();
        assert!(multi > single);
    }

    #[test]
    fn tasks_start_after_job_start() {
        let (cfg, mut svc, spec) = setup(6);
        let sched = Scheduler::new(&cfg);
        let start = SimTime::from_secs_f64(100.0);
        let run = &sched.run_jobs(&[spec], &mut svc, start)[0];
        for t in &run.tasks {
            assert!(t.start.unwrap() >= start);
            assert!(t.finish.unwrap() >= t.start.unwrap());
        }
        // reduces start only after every map finished (shuffle barrier)
        let map_end = run
            .tasks
            .iter()
            .filter(|t| t.kind == TaskKind::Map)
            .map(|t| t.finish.unwrap())
            .max()
            .unwrap();
        for t in run.tasks.iter().filter(|t| t.kind == TaskKind::Reduce) {
            assert!(t.start.unwrap() >= map_end);
        }
    }
}
