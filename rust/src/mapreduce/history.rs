//! Job History Server — the training-data source (§5.1, Table 3).
//!
//! Hadoop's history server exposes per-job and per-task state over REST;
//! the paper extracts its SVM training features from exactly these records.
//! Our simulated server stores `HistoryRecord`s with the Table 3 schema and
//! additionally emits *snapshots* of a job at several points of its
//! lifecycle (New -> Initiated -> Running(p%) -> terminal), because the
//! Table 4 labeling rules are defined over in-flight states, not just
//! completed jobs.

use crate::cache::CacheAffinity;
use crate::sim::{SimDuration, SimTime};

use super::job::{JobId, JobStatus};
use super::scheduler::JobRun;
use super::task::{TaskKind, TaskStatus};

/// One Table 3 record: a (job, task-type) state observation.
#[derive(Debug, Clone)]
pub struct HistoryRecord {
    pub job: JobId,
    pub job_name: String,
    pub maps_total: usize,
    pub maps_completed: usize,
    pub reduces_total: usize,
    pub reduces_completed: usize,
    pub job_status: JobStatus,
    pub affinity: CacheAffinity,
    pub start_time: SimTime,
    pub finish_time: Option<SimTime>,
    pub task_kind: TaskKind,
    pub task_status: TaskStatus,
    pub avg_map_time: SimDuration,
    pub avg_reduce_time: SimDuration,
    /// Task progress in [0, 1].
    pub progress: f64,
}

/// The simulated job-history server.
#[derive(Debug, Default)]
pub struct HistoryServer {
    records: Vec<HistoryRecord>,
}

impl HistoryServer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, rec: HistoryRecord) {
        self.records.push(rec);
    }

    pub fn records(&self) -> &[HistoryRecord] {
        &self.records
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn clear(&mut self) {
        self.records.clear();
    }

    /// Ingest a completed `JobRun`, emitting lifecycle snapshots:
    /// * New / Initiated (queue + scheduling states),
    /// * Running at 25/50/75% map progress (map running, reduce waiting),
    /// * Running with maps done (reduce running),
    /// * the terminal state.
    pub fn ingest(&mut self, run: &JobRun) {
        let spec = &run.spec;
        let base = HistoryRecord {
            job: spec.id,
            job_name: spec.app.clone(),
            maps_total: spec.n_maps(),
            maps_completed: 0,
            reduces_total: spec.n_reduces,
            reduces_completed: 0,
            job_status: JobStatus::New,
            affinity: spec.affinity,
            start_time: run.start,
            finish_time: None,
            task_kind: TaskKind::Map,
            task_status: TaskStatus::New,
            avg_map_time: SimDuration::ZERO,
            avg_reduce_time: SimDuration::ZERO,
            progress: 0.0,
        };

        // queued
        self.push(base.clone());
        // initiated / scheduling
        self.push(HistoryRecord {
            job_status: JobStatus::Initiated,
            task_status: TaskStatus::Scheduled,
            ..base.clone()
        });
        // running map snapshots
        for pct in [0.25, 0.5, 0.75] {
            let done = ((spec.n_maps() as f64) * pct) as usize;
            self.push(HistoryRecord {
                job_status: JobStatus::Running,
                maps_completed: done,
                task_kind: TaskKind::Map,
                task_status: TaskStatus::Running,
                avg_map_time: run.avg_map_time(),
                progress: pct,
                ..base.clone()
            });
        }
        // maps finished, reduces running
        self.push(HistoryRecord {
            job_status: JobStatus::Running,
            maps_completed: run.maps_completed(),
            task_kind: TaskKind::Reduce,
            task_status: TaskStatus::Running,
            avg_map_time: run.avg_map_time(),
            avg_reduce_time: run.avg_reduce_time(),
            progress: 0.5,
            ..base.clone()
        });
        // terminal
        self.push(HistoryRecord {
            job_status: run.status,
            maps_completed: run.maps_completed(),
            reduces_completed: run.reduces_completed(),
            task_kind: TaskKind::Reduce,
            task_status: match run.status {
                JobStatus::Succeeded => TaskStatus::Succeeded,
                JobStatus::Failed => TaskStatus::Failed,
                JobStatus::Killed => TaskStatus::Killed,
                _ => TaskStatus::Running,
            },
            finish_time: Some(run.finish),
            avg_map_time: run.avg_map_time(),
            avg_reduce_time: run.avg_reduce_time(),
            progress: 1.0,
            ..base
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdfs::BlockId;
    use crate::mapreduce::job::JobSpec;
    use crate::mapreduce::task::Task;

    fn fake_run() -> JobRun {
        let spec = JobSpec {
            id: JobId(3),
            app: "Grep".into(),
            affinity: CacheAffinity::High,
            input_blocks: vec![BlockId(0), BlockId(1)],
            n_reduces: 1,
            map_cpu_s_per_mb: 0.005,
            reduce_cpu_s_per_mb: 0.002,
            shuffle_ratio: 0.05,
            stages: 1,
        };
        let mut tasks = vec![
            Task::map(spec.id, 0, BlockId(0)),
            Task::map(spec.id, 1, BlockId(1)),
            Task::reduce(spec.id, 0),
        ];
        for (i, t) in tasks.iter_mut().enumerate() {
            t.status = TaskStatus::Succeeded;
            t.start = Some(SimTime((i as u64) * 100));
            t.finish = Some(SimTime((i as u64) * 100 + 50));
        }
        JobRun {
            spec,
            status: JobStatus::Succeeded,
            start: SimTime::ZERO,
            finish: SimTime(1000),
            tasks,
            cache_hits: 1,
            cache_misses: 1,
            bytes_from_cache: 64,
            bytes_from_disk: 64,
            failed_attempts: 0,
            killed_attempts: 0,
        }
    }

    #[test]
    fn ingest_emits_lifecycle_snapshots() {
        let mut hs = HistoryServer::new();
        hs.ingest(&fake_run());
        assert_eq!(hs.len(), 7);
        let states: Vec<JobStatus> = hs.records().iter().map(|r| r.job_status).collect();
        assert_eq!(states[0], JobStatus::New);
        assert_eq!(states[1], JobStatus::Initiated);
        assert!(states[2..6].iter().all(|s| *s == JobStatus::Running));
        assert_eq!(states[6], JobStatus::Succeeded);
        let last = &hs.records()[6];
        assert_eq!(last.maps_completed, 2);
        assert_eq!(last.reduces_completed, 1);
        assert!(last.finish_time.is_some());
        assert!(last.avg_map_time > SimDuration::ZERO);
    }

    #[test]
    fn clear_resets() {
        let mut hs = HistoryServer::new();
        hs.ingest(&fake_run());
        assert!(!hs.is_empty());
        hs.clear();
        assert!(hs.is_empty());
    }
}
