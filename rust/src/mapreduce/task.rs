//! Map/Reduce tasks and their lifecycle states (Table 3/4).

use crate::hdfs::{BlockId, DataNodeId};
use crate::sim::SimTime;

use super::job::JobId;

/// Task kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    Map,
    Reduce,
}

impl TaskKind {
    pub fn name(self) -> &'static str {
        match self {
            TaskKind::Map => "map",
            TaskKind::Reduce => "reduce",
        }
    }
}

/// Task lifecycle states — Table 3: New, Scheduled, Running, Succeeded,
/// Failed, Killed (the labeling guidelines of Table 4 additionally use a
/// "Waiting" phase for reduces which maps to `New` here + the shuffle
/// barrier).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskStatus {
    New,
    Scheduled,
    Running,
    Succeeded,
    Failed,
    Killed,
}

impl TaskStatus {
    pub fn name(self) -> &'static str {
        match self {
            TaskStatus::New => "new",
            TaskStatus::Scheduled => "scheduled",
            TaskStatus::Running => "running",
            TaskStatus::Succeeded => "succeeded",
            TaskStatus::Failed => "failed",
            TaskStatus::Killed => "killed",
        }
    }

    pub fn is_terminal(self) -> bool {
        matches!(self, TaskStatus::Succeeded | TaskStatus::Failed | TaskStatus::Killed)
    }
}

/// A task instance tracked by the scheduler.
#[derive(Debug, Clone)]
pub struct Task {
    pub job: JobId,
    pub kind: TaskKind,
    pub index: usize,
    pub status: TaskStatus,
    /// The input block (map tasks only).
    pub input: Option<BlockId>,
    /// Node the task was placed on (once scheduled).
    pub node: Option<DataNodeId>,
    pub start: Option<SimTime>,
    pub finish: Option<SimTime>,
}

impl Task {
    pub fn map(job: JobId, index: usize, input: BlockId) -> Self {
        Task {
            job,
            kind: TaskKind::Map,
            index,
            status: TaskStatus::New,
            input: Some(input),
            node: None,
            start: None,
            finish: None,
        }
    }

    pub fn reduce(job: JobId, index: usize) -> Self {
        Task {
            job,
            kind: TaskKind::Reduce,
            index,
            status: TaskStatus::New,
            input: None,
            node: None,
            start: None,
            finish: None,
        }
    }

    pub fn duration(&self) -> Option<crate::sim::SimDuration> {
        match (self.start, self.finish) {
            (Some(s), Some(f)) => Some(f - s),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_and_duration() {
        let mut t = Task::map(JobId(0), 0, BlockId(5));
        assert_eq!(t.status, TaskStatus::New);
        assert_eq!(t.duration(), None);
        t.status = TaskStatus::Running;
        t.start = Some(SimTime(100));
        t.finish = Some(SimTime(250));
        t.status = TaskStatus::Succeeded;
        assert!(t.status.is_terminal());
        assert_eq!(t.duration().unwrap().micros(), 150);
    }

    #[test]
    fn reduce_has_no_input_block() {
        let t = Task::reduce(JobId(0), 3);
        assert_eq!(t.input, None);
        assert_eq!(t.kind.name(), "reduce");
    }
}
