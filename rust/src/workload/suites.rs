//! The paper's Table 8 workloads: six mixes of four concurrent MapReduce
//! applications with shared inputs.
//!
//! Sharing structure from §6.4.2: Grep, WordCount and Sort read the same
//! random-text input; Aggregation and Join share their (Hive) input.
//! Input sizes are the paper's, scaled by `scale` (the default 1/100 turns
//! 257 GB workloads into ~2.6 GB simulations that finish in seconds while
//! preserving block-level sharing).

use crate::hdfs::BlockId;
use crate::mapreduce::job::{JobId, JobSpec};
use crate::util::bytes::GB;

use super::apps::App;
use super::datagen::Cluster;

/// One Table 8 row.
#[derive(Debug, Clone)]
pub struct WorkloadDef {
    /// Suite name (W1..W6).
    pub name: &'static str,
    /// The four applications the suite mixes.
    pub apps: [App; 4],
    /// Paper's total input size in GB (Table 8's "Input data size").
    pub input_gb: f64,
}

/// Table 8, verbatim.
pub const WORKLOADS: [WorkloadDef; 6] = [
    WorkloadDef {
        name: "W1",
        apps: [App::Aggregation, App::Grep, App::Join, App::WordCount],
        input_gb: 257.3,
    },
    WorkloadDef {
        name: "W2",
        apps: [App::Aggregation, App::Grep, App::Sort, App::WordCount],
        input_gb: 262.9,
    },
    WorkloadDef {
        name: "W3",
        apps: [App::Aggregation, App::WordCount, App::Grep, App::Grep],
        input_gb: 376.2,
    },
    WorkloadDef {
        name: "W4",
        apps: [App::Aggregation, App::Sort, App::Grep, App::Grep],
        input_gb: 446.7,
    },
    WorkloadDef {
        name: "W5",
        apps: [App::Grep, App::Grep, App::Sort, App::WordCount],
        input_gb: 254.3,
    },
    WorkloadDef {
        name: "W6",
        apps: [App::Aggregation, App::Grep, App::Join, App::Sort],
        input_gb: 377.1,
    },
];

/// Look up a Table 8 workload suite by its `W1`..`W6` name.
pub fn workload_by_name(name: &str) -> Option<&'static WorkloadDef> {
    WORKLOADS.iter().find(|w| w.name.eq_ignore_ascii_case(name))
}

/// Instantiate a workload on a cluster: registers the shared input files
/// and returns one `JobSpec` per application.
///
/// Text-population apps (Grep/WordCount/Sort) share the "text" input;
/// Hive apps (Aggregation/Join) share the "hive" input. The paper's total
/// input size is split between the two populations in proportion to how
/// many apps use each.
pub fn instantiate(
    def: &WorkloadDef,
    cluster: &mut Cluster,
    scale: f64,
    job_id_base: u64,
) -> Vec<JobSpec> {
    assert!(scale > 0.0, "scale must be positive");
    let total_bytes = (def.input_gb * scale * GB as f64) as u64;
    let n_text = def
        .apps
        .iter()
        .filter(|a| matches!(a, App::Grep | App::WordCount | App::Sort))
        .count();
    let n_hive = 4 - n_text;
    let text_bytes =
        (total_bytes as f64 * n_text as f64 / 4.0) as u64;
    let hive_bytes = total_bytes - text_bytes;

    let text_file = if n_text > 0 {
        Some(cluster.add_input(&format!("{}/text", def.name), text_bytes.max(1)))
    } else {
        None
    };
    let hive_file = if n_hive > 0 {
        Some(cluster.add_input(&format!("{}/hive", def.name), hive_bytes.max(1)))
    } else {
        None
    };

    def.apps
        .iter()
        .enumerate()
        .map(|(i, app)| {
            let file = match app {
                App::Grep | App::WordCount | App::Sort => text_file.unwrap(),
                App::Join | App::Aggregation => hive_file.unwrap(),
            };
            let blocks: Vec<BlockId> = cluster.namenode.files.blocks_of(file).to_vec();
            app.job(JobId(job_id_base + i as u64), blocks)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    #[test]
    fn table8_shapes() {
        assert_eq!(WORKLOADS.len(), 6);
        assert_eq!(workload_by_name("w3").unwrap().apps[1], App::WordCount);
        assert!(workload_by_name("w9").is_none());
        // W4 is the largest workload in the paper.
        let max = WORKLOADS
            .iter()
            .max_by(|a, b| a.input_gb.partial_cmp(&b.input_gb).unwrap())
            .unwrap();
        assert_eq!(max.name, "W4");
    }

    #[test]
    fn instantiate_shares_inputs() {
        let cfg = ClusterConfig::default();
        let mut cluster = Cluster::provision(&cfg);
        let jobs = instantiate(&WORKLOADS[4], &mut cluster, 0.01, 0); // W5
        assert_eq!(jobs.len(), 4);
        // W5 = Grep, Grep, Sort, WordCount: all four share the text input.
        let first = &jobs[0].input_blocks;
        for job in &jobs[1..] {
            assert_eq!(&job.input_blocks, first, "W5 apps must share input");
        }
    }

    #[test]
    fn instantiate_splits_text_and_hive() {
        let cfg = ClusterConfig::default();
        let mut cluster = Cluster::provision(&cfg);
        let jobs = instantiate(&WORKLOADS[0], &mut cluster, 0.01, 10); // W1
        // W1 = Aggregation, Grep, Join, WordCount.
        let agg = &jobs[0];
        let grep = &jobs[1];
        let join = &jobs[2];
        let wc = &jobs[3];
        assert_eq!(agg.input_blocks, join.input_blocks, "hive apps share");
        assert_eq!(grep.input_blocks, wc.input_blocks, "text apps share");
        assert_ne!(agg.input_blocks, grep.input_blocks);
        assert_eq!(jobs[0].id, JobId(10));
    }

    #[test]
    fn scale_controls_block_count() {
        let cfg = ClusterConfig::default();
        let mut c1 = Cluster::provision(&cfg);
        let mut c2 = Cluster::provision(&cfg);
        let j1 = instantiate(&WORKLOADS[0], &mut c1, 0.005, 0);
        let j2 = instantiate(&WORKLOADS[0], &mut c2, 0.02, 0);
        let b1: usize = j1.iter().map(|j| j.n_maps()).sum();
        let b2: usize = j2.iter().map(|j| j.n_maps()).sum();
        assert!(b2 > b1);
    }
}
