//! HiBench-like application models (paper §6.1).
//!
//! The five applications the paper evaluates, with the resource profiles
//! its text describes: WordCount (CPU-intensive), Sort (I/O-bound), Grep
//! (mixed), Join (multi-stage), Aggregation (Hive aggregation query); and
//! the cache-affinity classes of §6.4.2: low (Sort), medium (WordCount,
//! Join), high (Grep, Aggregation).

use crate::cache::CacheAffinity;
use crate::hdfs::BlockId;
use crate::mapreduce::job::{JobId, JobSpec};

/// The evaluated applications.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum App {
    /// CPU-intensive token counting.
    WordCount,
    /// I/O-bound single-pass sort.
    Sort,
    /// Scan with per-record match cost.
    Grep,
    /// Multi-stage join (two chained MapReduce stages).
    Join,
    /// Hive-style aggregation query.
    Aggregation,
}

/// Every evaluated application, in presentation order.
pub const ALL_APPS: [App; 5] =
    [App::WordCount, App::Sort, App::Grep, App::Join, App::Aggregation];

impl App {
    /// Canonical display name.
    pub fn name(self) -> &'static str {
        match self {
            App::WordCount => "WordCount",
            App::Sort => "Sort",
            App::Grep => "Grep",
            App::Join => "Join",
            App::Aggregation => "Aggregation",
        }
    }

    /// Parse a (case-insensitive) application name.
    pub fn from_name(s: &str) -> Option<App> {
        match s.to_ascii_lowercase().as_str() {
            "wordcount" => Some(App::WordCount),
            "sort" => Some(App::Sort),
            "grep" => Some(App::Grep),
            "join" => Some(App::Join),
            "aggregation" => Some(App::Aggregation),
            _ => None,
        }
    }

    /// Cache affinity classes from §6.4.2.
    pub fn affinity(self) -> CacheAffinity {
        match self {
            App::Sort => CacheAffinity::Low,
            App::WordCount | App::Join => CacheAffinity::Medium,
            App::Grep | App::Aggregation => CacheAffinity::High,
        }
    }

    /// CPU seconds per MB of input in the map phase. WordCount is
    /// CPU-intensive; Sort does almost no per-record compute; Grep is a
    /// scan with matching cost; Join/Aggregation sit between.
    pub fn map_cpu_s_per_mb(self) -> f64 {
        match self {
            App::WordCount => 0.035,
            App::Sort => 0.004,
            App::Grep => 0.010,
            App::Join => 0.018,
            App::Aggregation => 0.015,
        }
    }

    /// CPU seconds per MB of shuffled data in the reduce phase.
    pub fn reduce_cpu_s_per_mb(self) -> f64 {
        match self {
            App::WordCount => 0.008,
            App::Sort => 0.012,
            App::Grep => 0.002,
            App::Join => 0.015,
            App::Aggregation => 0.010,
        }
    }

    /// Intermediate-data volume as a fraction of the input volume.
    /// Sort shuffles everything; Grep's matches are tiny; WordCount's
    /// combiner compresses heavily.
    pub fn shuffle_ratio(self) -> f64 {
        match self {
            App::WordCount => 0.15,
            App::Sort => 1.0,
            App::Grep => 0.02,
            App::Join => 0.6,
            App::Aggregation => 0.25,
        }
    }

    /// Chained MapReduce stages (Join is the paper's multi-stage example).
    pub fn stages(self) -> usize {
        match self {
            App::Join => 2,
            _ => 1,
        }
    }

    /// Reduce-task count heuristic for an input of `n_blocks`.
    pub fn n_reduces(self, n_blocks: usize) -> usize {
        match self {
            App::Grep => 1,
            App::Sort => (n_blocks / 4).clamp(1, 16),
            _ => (n_blocks / 8).clamp(1, 8),
        }
    }

    /// Build a `JobSpec` over concrete input blocks.
    pub fn job(self, id: JobId, input_blocks: Vec<BlockId>) -> JobSpec {
        let n = input_blocks.len();
        JobSpec {
            id,
            app: self.name().to_string(),
            affinity: self.affinity(),
            input_blocks,
            n_reduces: self.n_reduces(n),
            map_cpu_s_per_mb: self.map_cpu_s_per_mb(),
            reduce_cpu_s_per_mb: self.reduce_cpu_s_per_mb(),
            shuffle_ratio: self.shuffle_ratio(),
            stages: self.stages(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn affinity_classes_match_paper() {
        assert_eq!(App::Sort.affinity(), CacheAffinity::Low);
        assert_eq!(App::WordCount.affinity(), CacheAffinity::Medium);
        assert_eq!(App::Join.affinity(), CacheAffinity::Medium);
        assert_eq!(App::Grep.affinity(), CacheAffinity::High);
        assert_eq!(App::Aggregation.affinity(), CacheAffinity::High);
    }

    #[test]
    fn resource_profiles_are_sane() {
        // WordCount is the most CPU-intensive; Sort the least.
        assert!(App::WordCount.map_cpu_s_per_mb() > App::Grep.map_cpu_s_per_mb());
        assert!(App::Grep.map_cpu_s_per_mb() > App::Sort.map_cpu_s_per_mb());
        // Sort is IO-bound: shuffles everything.
        assert_eq!(App::Sort.shuffle_ratio(), 1.0);
        assert!(App::Grep.shuffle_ratio() < 0.1);
        // Join is the only multi-stage app.
        assert_eq!(App::Join.stages(), 2);
        assert_eq!(App::WordCount.stages(), 1);
    }

    #[test]
    fn job_construction() {
        let job = App::Grep.job(JobId(1), vec![BlockId(0), BlockId(1)]);
        assert_eq!(job.app, "Grep");
        assert_eq!(job.n_maps(), 2);
        assert_eq!(job.n_reduces, 1);
    }

    #[test]
    fn name_round_trip() {
        for app in ALL_APPS {
            assert_eq!(App::from_name(app.name()), Some(app));
        }
        assert_eq!(App::from_name("bogus"), None);
    }
}
