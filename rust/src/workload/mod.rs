//! Workload models: the five HiBench-like applications the paper evaluates,
//! synthetic dataset registration, seeded block-request traces (Fig 3), the
//! Table 8 workload suites (Fig 5/6), and multi-stage job DAGs whose stage
//! outputs are cacheable blocks with recompute costs.

/// The five paper applications and their resource/affinity profiles.
pub mod apps;
/// Multi-stage DAG jobs (chain/diamond/fan-in) and the recompute-cost model.
pub mod dag;
/// Synthetic HDFS dataset registration.
pub mod datagen;
/// The Table 8 workload suites (Fig 5/6).
pub mod suites;
/// Seeded block-request trace generators (Fig 3).
pub mod trace;

pub use apps::{App, ALL_APPS};
pub use dag::{chain_suite, diamond_suite, DagJob, DagStage};
pub use datagen::Cluster;
pub use suites::{instantiate, workload_by_name, WorkloadDef, WORKLOADS};
pub use trace::{
    fig3_trace, generate as generate_trace, scan_storm_trace, BlockRequest, TraceConfig,
};
