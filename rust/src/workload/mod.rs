//! Workload models: the five HiBench-like applications the paper evaluates,
//! synthetic dataset registration, seeded block-request traces (Fig 3), and
//! the Table 8 workload suites (Fig 5/6).

pub mod apps;
pub mod datagen;
pub mod suites;
pub mod trace;

pub use apps::{App, ALL_APPS};
pub use datagen::Cluster;
pub use suites::{instantiate, workload_by_name, WorkloadDef, WORKLOADS};
pub use trace::{
    fig3_trace, generate as generate_trace, scan_storm_trace, BlockRequest, TraceConfig,
};
