//! Synthetic dataset registration — the stand-in for the paper's Gutenberg
//! corpus and HiBench's random text generator (see DESIGN.md §2: only block
//! counts, sizes and sharing structure matter to the cache layer).

use crate::config::ClusterConfig;
use crate::hdfs::{BlockKind, DataNode, DataNodeId, NameNode};
use crate::util::rng::Pcg64;

/// A freshly provisioned simulated cluster.
pub struct Cluster {
    /// The cluster parameters the dataset was provisioned with.
    pub cfg: ClusterConfig,
    /// Block -> location metadata.
    pub namenode: NameNode,
    /// Per-node cache + disk state.
    pub datanodes: Vec<DataNode>,
}

impl Cluster {
    /// Build a cluster per the config: one NameNode, `datanodes` DataNodes
    /// with the configured off-heap cache capacity.
    pub fn provision(cfg: &ClusterConfig) -> Self {
        cfg.validate().expect("invalid cluster config");
        let mut seed_rng = Pcg64::new(cfg.seed, 0xC1);
        let namenode = NameNode::new(cfg.datanodes, cfg.replication, seed_rng.fork(1));
        let datanodes = (0..cfg.datanodes)
            .map(|i| DataNode::new(DataNodeId(i as u32), cfg.cache_capacity_per_node))
            .collect();
        Cluster { cfg: cfg.clone(), namenode, datanodes }
    }

    /// Register an input dataset of `size` bytes under `name`. Returns the
    /// file id.
    pub fn add_input(&mut self, name: &str, size: u64) -> u64 {
        self.namenode.register_file(
            name,
            size,
            self.cfg.block_size,
            BlockKind::Input,
            &mut self.datanodes,
        )
    }

    /// Register an intermediate dataset (shuffle spill / multi-stage).
    pub fn add_intermediate(&mut self, name: &str, size: u64) -> u64 {
        self.namenode.register_file(
            name,
            size,
            self.cfg.block_size,
            BlockKind::Intermediate,
            &mut self.datanodes,
        )
    }

    /// Total cache capacity across DataNodes.
    pub fn total_cache_capacity(&self) -> u64 {
        self.datanodes.iter().map(|d| d.cache_capacity()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::{GB, MB};

    #[test]
    fn provision_matches_config() {
        let cfg = ClusterConfig::default();
        let cluster = Cluster::provision(&cfg);
        assert_eq!(cluster.datanodes.len(), 9);
        assert_eq!(
            cluster.total_cache_capacity(),
            9 * (1.5 * GB as f64) as u64
        );
    }

    #[test]
    fn add_input_registers_blocks_and_replicas() {
        let cfg = ClusterConfig { block_size: 64 * MB, ..Default::default() };
        let mut cluster = Cluster::provision(&cfg);
        let fid = cluster.add_input("corpus", 2 * GB);
        let blocks = cluster.namenode.files.blocks_of(fid);
        assert_eq!(blocks.len(), 32);
        // Every block has `replication` replicas stored on real DataNodes.
        for &b in blocks {
            let reps = cluster.namenode.replicas_of(b);
            assert_eq!(reps.len(), 3);
            for dn in reps {
                assert!(cluster.datanodes[dn.0 as usize].has_block(b));
            }
        }
    }

    #[test]
    fn deterministic_placement_for_seed() {
        let cfg = ClusterConfig::default();
        let mut a = Cluster::provision(&cfg);
        let mut b = Cluster::provision(&cfg);
        let fa = a.add_input("x", GB);
        let fb = b.add_input("x", GB);
        for (&ba, &bb) in a
            .namenode
            .files
            .blocks_of(fa)
            .iter()
            .zip(b.namenode.files.blocks_of(fb))
        {
            assert_eq!(a.namenode.replicas_of(ba), b.namenode.replicas_of(bb));
        }
    }
}
