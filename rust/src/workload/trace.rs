//! Block-request trace generation for the hit-ratio experiments (Fig 3 /
//! Table 7).
//!
//! The paper replays "the same sequence of requested data for each
//! mechanism" over a 2 GB input. A MapReduce request stream mixes two
//! behaviours: *shared/hot* blocks that several applications re-read
//! (Zipf-skewed popularity) and *single-pass* blocks scanned once and never
//! again (the cache pollution source H-SVM-LRU targets). The generator is
//! seeded, so every policy sees the identical sequence.
//!
//! Each request carries its ground-truth future-reuse bit (computed by a
//! backward scan), which the *request-awareness* training scenario of §5.1
//! uses directly as the SVM label.

use crate::cache::CacheAffinity;
use crate::hdfs::{BlockId, BlockKind};
use crate::sim::SimTime;
use crate::util::rng::{Pcg64, Zipf};

/// One request in a trace.
#[derive(Debug, Clone)]
pub struct BlockRequest {
    /// Simulated arrival time.
    pub time: SimTime,
    /// Requested block.
    pub block: BlockId,
    /// Block size in bytes.
    pub size: u64,
    /// Block type (input vs intermediate — the Table 2 "type" feature).
    pub kind: BlockKind,
    /// Cache affinity of the requesting application.
    pub affinity: CacheAffinity,
    /// Ground truth: is this block requested again later in the trace?
    pub reused_later: bool,
    /// CPU seconds to regenerate the block if evicted (0.0 for the flat
    /// trace generators; nonzero only for DAG stage outputs).
    pub recompute_cost: f64,
}

/// Trace generator parameters.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Number of distinct hot (shareable) blocks.
    pub hot_blocks: usize,
    /// Number of single-pass blocks (requested exactly once).
    pub cold_blocks: usize,
    /// Total requests to emit.
    pub requests: usize,
    /// Zipf skew of hot-block popularity.
    pub zipf_s: f64,
    /// Fraction of requests that go to the cold (single-pass) population.
    pub cold_fraction: f64,
    /// Uniform block size in bytes (the paper's fig 3 uses equal blocks).
    pub block_size: u64,
    /// Mean inter-arrival time in seconds.
    pub mean_interarrival_s: f64,
    /// RNG seed — identical seeds produce identical traces.
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            hot_blocks: 16,
            cold_blocks: 64,
            requests: 512,
            zipf_s: 0.9,
            cold_fraction: 0.45,
            block_size: 128 * crate::util::bytes::MB,
            mean_interarrival_s: 0.5,
            seed: 42,
        }
    }
}

/// Ground-truth future reuse by backward scan: `reused[i]` is true iff
/// `blocks[i]` appears again after position `i`.
fn future_reuse(blocks: &[BlockId]) -> Vec<bool> {
    let mut seen = std::collections::HashSet::new();
    let mut reused = vec![false; blocks.len()];
    for (i, b) in blocks.iter().enumerate().rev() {
        reused[i] = seen.contains(b);
        seen.insert(*b);
    }
    reused
}

/// Generate a trace. Cold (single-pass, intermediate-data) blocks are dealt
/// out sequentially — each appears exactly once, a sustained pollution
/// stream like MapReduce shuffle spills; hot (shared input) blocks are
/// drawn from a Zipf distribution.
pub fn generate(cfg: &TraceConfig) -> Vec<BlockRequest> {
    assert!(cfg.hot_blocks > 0 && cfg.requests > 0, "empty trace config");
    let mut rng = Pcg64::new(cfg.seed, 0xF163);
    let zipf = Zipf::new(cfg.hot_blocks, cfg.zipf_s);
    // Hot blocks get ids [0, hot); cold blocks [hot, hot + cold).
    let affinities = [CacheAffinity::Low, CacheAffinity::Medium, CacheAffinity::High];
    let mut next_cold = 0usize;
    let mut t = 0.0f64;
    let mut raw: Vec<(BlockId, bool, CacheAffinity, f64)> = Vec::with_capacity(cfg.requests);
    for _ in 0..cfg.requests {
        t += rng.gen_exp(1.0 / cfg.mean_interarrival_s.max(1e-9));
        let is_cold = next_cold < cfg.cold_blocks && rng.gen_bool(cfg.cold_fraction);
        let block = if is_cold {
            let b = BlockId((cfg.hot_blocks + next_cold) as u64);
            next_cold += 1;
            b
        } else {
            BlockId(zipf.sample(&mut rng) as u64)
        };
        let affinity = *rng.choose(&affinities);
        raw.push((block, is_cold, affinity, t));
    }
    // Backward scan for ground-truth reuse.
    let reused = future_reuse(&raw.iter().map(|(b, ..)| *b).collect::<Vec<_>>());
    raw.into_iter()
        .zip(reused)
        .map(|((block, is_cold, affinity, secs), reused_later)| BlockRequest {
            time: SimTime::from_secs_f64(secs),
            block,
            size: cfg.block_size,
            // Single-pass blocks model shuffle/intermediate data; shared
            // blocks are job input — the Table 2 "type" feature.
            kind: if is_cold { BlockKind::Intermediate } else { BlockKind::Input },
            affinity,
            reused_later,
            recompute_cost: 0.0,
        })
        .collect()
}

/// The paper's fig 3 trace: a 2 GB shared input (`2GB / block_size` hot
/// blocks, Zipf-reused across jobs) interleaved with a sustained stream of
/// single-pass intermediate blocks — the cache-pollution regime H-SVM-LRU
/// targets. Half of all requests are pollution, so a recency-only LRU
/// thrashes at small cache sizes while the class-aware policy protects the
/// reused inputs.
pub fn fig3_trace(block_size: u64, seed: u64) -> Vec<BlockRequest> {
    let hot = (2 * crate::util::bytes::GB / block_size) as usize;
    let requests = hot * 12;
    generate(&TraceConfig {
        hot_blocks: hot,
        cold_blocks: requests, // never exhausted: sustained pollution
        requests,
        zipf_s: 1.1,
        cold_fraction: 0.4,
        block_size,
        mean_interarrival_s: 0.2,
        seed,
    })
}

/// Number of hot (repeatedly re-read) blocks in [`scan_storm_trace`].
pub const SCAN_STORM_HOT_BLOCKS: usize = 6;

/// The canonical cache-pollution adversary: a sustained sequential-scan
/// flood interleaved with a small hot set (§4's pollution definition,
/// weaponized). Every round shuffles accesses to the `SCAN_STORM_HOT_BLOCKS`
/// hot input blocks between a burst of fresh, strictly sequential scan
/// blocks that are never requested again. The scan burst alone exceeds the
/// experiments' default 8-block cache, so a recency-only LRU with
/// admit-everything evicts the entire hot set every round and hits almost
/// never — while a frequency/ghost/SVM admission layer refuses the flood
/// and keeps the hot set resident. This is the trace the `repro admission`
/// sweep must win on.
pub fn scan_storm_trace(block_size: u64, seed: u64) -> Vec<BlockRequest> {
    const ROUNDS: usize = 64;
    const SCANS_PER_ROUND: usize = 10;
    let hot = SCAN_STORM_HOT_BLOCKS;
    let mut rng = Pcg64::new(seed, 0x5C4A);
    let mut next_scan = hot as u64;
    // (block, is_scan) per request; hot and scan slots interleave in a
    // seeded shuffled order so neither stream forms one contiguous run.
    let mut raw: Vec<(BlockId, bool)> = Vec::with_capacity(ROUNDS * (hot + SCANS_PER_ROUND));
    for _ in 0..ROUNDS {
        let mut slots: Vec<Option<usize>> = (0..hot).map(Some).collect();
        slots.resize(hot + SCANS_PER_ROUND, None);
        rng.shuffle(&mut slots);
        for slot in slots {
            match slot {
                Some(h) => raw.push((BlockId(h as u64), false)),
                None => {
                    raw.push((BlockId(next_scan), true));
                    next_scan += 1;
                }
            }
        }
    }
    let reused = future_reuse(&raw.iter().map(|(b, _)| *b).collect::<Vec<_>>());
    let mut t = 0.0f64;
    raw.into_iter()
        .zip(reused)
        .map(|((block, is_scan), reused_later)| {
            t += rng.gen_exp(1.0 / 0.1);
            BlockRequest {
                time: SimTime::from_secs_f64(t),
                block,
                size: block_size,
                kind: if is_scan { BlockKind::Intermediate } else { BlockKind::Input },
                affinity: if is_scan { CacheAffinity::Low } else { CacheAffinity::High },
                reused_later,
                recompute_cost: 0.0,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::MB;

    #[test]
    fn deterministic_for_seed() {
        let cfg = TraceConfig::default();
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.block, y.block);
            assert_eq!(x.time, y.time);
            assert_eq!(x.reused_later, y.reused_later);
        }
    }

    #[test]
    fn ground_truth_reuse_is_correct() {
        let trace = generate(&TraceConfig::default());
        for (i, req) in trace.iter().enumerate() {
            let actually_reused = trace[i + 1..].iter().any(|r| r.block == req.block);
            assert_eq!(req.reused_later, actually_reused, "at position {i}");
        }
    }

    #[test]
    fn cold_blocks_appear_once() {
        let cfg = TraceConfig::default();
        let trace = generate(&cfg);
        for cold_id in cfg.hot_blocks..cfg.hot_blocks + cfg.cold_blocks {
            let n = trace.iter().filter(|r| r.block == BlockId(cold_id as u64)).count();
            assert!(n <= 1, "cold block {cold_id} appeared {n} times");
        }
    }

    #[test]
    fn times_are_monotone() {
        let trace = generate(&TraceConfig::default());
        for w in trace.windows(2) {
            assert!(w[0].time <= w[1].time);
        }
    }

    #[test]
    fn scan_storm_is_deterministic_and_labeled() {
        let a = scan_storm_trace(64 * MB, 9);
        let b = scan_storm_trace(64 * MB, 9);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.block, x.time, x.reused_later), (y.block, y.time, y.reused_later));
        }
        for w in a.windows(2) {
            assert!(w[0].time <= w[1].time);
        }
        for (i, req) in a.iter().enumerate() {
            let actually = a[i + 1..].iter().any(|r| r.block == req.block);
            assert_eq!(req.reused_later, actually, "ground truth at {i}");
        }
    }

    #[test]
    fn scan_storm_scans_are_single_pass_and_dominate() {
        let trace = scan_storm_trace(64 * MB, 4);
        let hot = SCAN_STORM_HOT_BLOCKS as u64;
        let mut scan_counts = std::collections::HashMap::new();
        let mut hot_requests = 0usize;
        for req in &trace {
            if req.block.0 < hot {
                hot_requests += 1;
                assert_eq!(req.kind, BlockKind::Input);
            } else {
                *scan_counts.entry(req.block).or_insert(0u32) += 1;
                assert_eq!(req.kind, BlockKind::Intermediate);
            }
        }
        assert!(scan_counts.values().all(|&n| n == 1), "scans must be single-pass");
        assert!(scan_counts.len() > trace.len() / 2, "the flood must dominate");
        assert!(hot_requests > 0);
        // Every hot block is re-read many times (the protected working set).
        for h in 0..hot {
            let n = trace.iter().filter(|r| r.block == BlockId(h)).count();
            assert!(n >= 32, "hot block {h} requested only {n} times");
        }
    }

    #[test]
    fn fig3_trace_covers_2gb() {
        let trace = fig3_trace(128 * MB, 7);
        let distinct: std::collections::HashSet<BlockId> =
            trace.iter().map(|r| r.block).collect();
        assert!(distinct.len() > 16, "hot inputs + pollution stream");
        let trace64 = fig3_trace(64 * MB, 7);
        let distinct64: std::collections::HashSet<BlockId> =
            trace64.iter().map(|r| r.block).collect();
        assert!(distinct64.len() > 32);
        // Mixed labels: both classes must be present for the SVM to learn.
        assert!(trace.iter().any(|r| r.reused_later));
        assert!(trace.iter().any(|r| !r.reused_later));
    }
}
