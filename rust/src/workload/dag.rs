//! Multi-stage job DAGs whose stage outputs are cacheable blocks.
//!
//! The paper's workloads are flat MapReduce jobs; real Hadoop pipelines
//! (Hive/Pig query plans, iterative analytics) chain stages into DAGs where
//! one stage's output is the next stage's input. Those intermediate
//! datasets are exactly the blocks H-SVM-LRU must reason about: they live
//! only in the cache (nothing re-reads them from HDFS once the pipeline
//! finishes), and evicting one that a downstream stage still needs forces
//! the producing stage's work to be partially re-run — a *recompute cost*
//! charged to simulated job time (cf. Spark's lineage-based recovery,
//! arXiv 1804.10563).
//!
//! A [`DagJob`] is a list of [`DagStage`]s in topological order: each stage
//! runs one of the five paper applications ([`App`]) over the outputs of
//! its `deps` plus any fresh HDFS `input_blocks`. Builders cover the three
//! shapes the experiments use — [`chain`] (map→shuffle→reduce pipelines),
//! [`diamond`] (one producer fanned out to two consumers, joined by a
//! sink) and [`fan_in`] (independent producers joined by one consumer) —
//! plus [`diamond_suite`]/[`chain_suite`] generators for N concurrent jobs
//! with disjoint block ranges.
//!
//! The cost model lives here too: [`stage_output_bytes`] sizes a stage's
//! output dataset from its input volume and the app's shuffle ratio, and
//! [`stage_recompute_cost_s`] prices regenerating it (map CPU over the
//! input plus reduce CPU over the shuffled fraction). `experiments::
//! dag_replay` divides that cost across the stage's output blocks and
//! attaches it to every cache access (`AccessContext::recompute_cost`,
//! SVM feature 8).

use crate::hdfs::BlockId;
use crate::util::bytes::MB;

use super::apps::App;

/// One stage of a DAG job: an application run over the outputs of earlier
/// stages and/or fresh HDFS input blocks.
#[derive(Debug, Clone)]
pub struct DagStage {
    /// Application profile executed by this stage.
    pub app: App,
    /// Indices of upstream stages (must be `<` this stage's own index)
    /// whose output blocks this stage reads.
    pub deps: Vec<usize>,
    /// Fresh HDFS input blocks read in addition to `deps` outputs. These
    /// are scheduled *before* the dependency outputs in the stage's map
    /// list, so a scan-heavy stage pressures the cache before it returns
    /// to the intermediate data it shares with sibling stages.
    pub input_blocks: Vec<BlockId>,
}

/// A multi-stage job: stages in topological order (deps point backwards).
#[derive(Debug, Clone)]
pub struct DagJob {
    /// Stable job identifier (disjoint across a suite).
    pub id: u64,
    /// Stages in topological order.
    pub stages: Vec<DagStage>,
}

impl DagJob {
    /// Build a job, validating the DAG shape: at least one stage, every
    /// dependency points to an earlier stage (acyclic by construction) and
    /// every stage has something to read.
    pub fn new(id: u64, stages: Vec<DagStage>) -> DagJob {
        assert!(!stages.is_empty(), "DAG job {id} has no stages");
        for (i, s) in stages.iter().enumerate() {
            for &d in &s.deps {
                assert!(d < i, "job {id} stage {i}: dep {d} is not an earlier stage");
            }
            assert!(
                !s.deps.is_empty() || !s.input_blocks.is_empty(),
                "job {id} stage {i} reads nothing"
            );
        }
        DagJob { id, stages }
    }

    /// Number of stages.
    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Wave level per stage: 0 for sources, `1 + max(dep levels)` otherwise.
    /// Stages of equal level across concurrent jobs run in the same wave.
    pub fn levels(&self) -> Vec<usize> {
        let mut lv = vec![0usize; self.stages.len()];
        for i in 0..self.stages.len() {
            lv[i] = self.stages[i]
                .deps
                .iter()
                .map(|&d| lv[d] + 1)
                .max()
                .unwrap_or(0);
        }
        lv
    }

    /// Stage indices no other stage depends on (the job finishes when its
    /// last sink finishes).
    pub fn sinks(&self) -> Vec<usize> {
        (0..self.stages.len())
            .filter(|&i| !self.stages.iter().any(|s| s.deps.contains(&i)))
            .collect()
    }

    /// Whether any stage consumes `stage`'s output (sinks write to HDFS
    /// instead of materializing cache blocks).
    pub fn has_consumer(&self, stage: usize) -> bool {
        self.stages.iter().any(|s| s.deps.contains(&stage))
    }

    /// All fresh HDFS blocks the job reads (sources + per-stage scans).
    pub fn input_blocks(&self) -> Vec<BlockId> {
        self.stages.iter().flat_map(|s| s.input_blocks.iter().copied()).collect()
    }
}

/// Output volume of a stage over `input_bytes` of input: the app's shuffle
/// ratio applied to the input (at least one byte, so every consumed stage
/// materializes something).
pub fn stage_output_bytes(app: App, input_bytes: u64) -> u64 {
    ((input_bytes as f64 * app.shuffle_ratio()) as u64).max(1)
}

/// CPU seconds to regenerate a stage's output from its (disk-resident)
/// inputs: map CPU over the input volume plus reduce CPU over the shuffled
/// fraction. This is what an evicted-then-requested output block costs,
/// pro-rated per block by the replay.
pub fn stage_recompute_cost_s(app: App, input_bytes: u64) -> f64 {
    let input_mb = input_bytes as f64 / MB as f64;
    input_mb * (app.map_cpu_s_per_mb() + app.shuffle_ratio() * app.reduce_cpu_s_per_mb())
}

/// Linear pipeline: `apps[0]` reads `input_blocks`, every later app reads
/// its predecessor's output.
pub fn chain(id: u64, apps: &[App], input_blocks: Vec<BlockId>) -> DagJob {
    assert!(!apps.is_empty(), "empty chain");
    let mut stages = vec![DagStage { app: apps[0], deps: Vec::new(), input_blocks }];
    for (i, &app) in apps.iter().enumerate().skip(1) {
        stages.push(DagStage { app, deps: vec![i - 1], input_blocks: Vec::new() });
    }
    DagJob::new(id, stages)
}

/// Diamond: `source` feeds two branches which join into `sink`. The first
/// branch additionally scans `scan_blocks` fresh HDFS blocks (read before
/// the shared intermediates — the cache-pollution pattern the cost-aware
/// policies must survive).
pub fn diamond(
    id: u64,
    source: App,
    branches: (App, App),
    sink: App,
    source_blocks: Vec<BlockId>,
    scan_blocks: Vec<BlockId>,
) -> DagJob {
    DagJob::new(
        id,
        vec![
            DagStage { app: source, deps: Vec::new(), input_blocks: source_blocks },
            DagStage { app: branches.0, deps: vec![0], input_blocks: scan_blocks },
            DagStage { app: branches.1, deps: vec![0], input_blocks: Vec::new() },
            DagStage { app: sink, deps: vec![1, 2], input_blocks: Vec::new() },
        ],
    )
}

/// Fan-in: independent `sources` joined by one `sink` stage.
pub fn fan_in(id: u64, sources: Vec<(App, Vec<BlockId>)>, sink: App) -> DagJob {
    assert!(!sources.is_empty(), "fan_in needs at least one source");
    let n = sources.len();
    let mut stages: Vec<DagStage> = sources
        .into_iter()
        .map(|(app, input_blocks)| DagStage { app, deps: Vec::new(), input_blocks })
        .collect();
    stages.push(DagStage { app: sink, deps: (0..n).collect(), input_blocks: Vec::new() });
    DagJob::new(id, stages)
}

/// Per-job block-id stride: suites give each job a disjoint id range so
/// traces from different jobs never alias.
pub const JOB_BLOCK_STRIDE: u64 = 1_000_000;

/// N concurrent diamond jobs: Sort produces a full-volume intermediate
/// dataset, a Grep branch scans `scan_blocks` fresh single-pass blocks
/// before re-reading it, an Aggregation branch re-reads it directly, and
/// an Aggregation sink joins the branches. Sort's shuffle ratio of 1.0
/// makes the shared intermediates maximally expensive to lose.
pub fn diamond_suite(n_jobs: usize, source_blocks: usize, scan_blocks: usize) -> Vec<DagJob> {
    (0..n_jobs as u64)
        .map(|j| {
            let base = j * JOB_BLOCK_STRIDE;
            let sources = (base..base + source_blocks as u64).map(BlockId).collect();
            let scans = (base + JOB_BLOCK_STRIDE / 2
                ..base + JOB_BLOCK_STRIDE / 2 + scan_blocks as u64)
                .map(BlockId)
                .collect();
            diamond(
                j,
                App::Sort,
                (App::Grep, App::Aggregation),
                App::Aggregation,
                sources,
                scans,
            )
        })
        .collect()
}

/// N concurrent three-stage chains (Sort → Join → Aggregation) over
/// disjoint inputs: the map→shuffle→reduce pipeline shape.
pub fn chain_suite(n_jobs: usize, source_blocks: usize) -> Vec<DagJob> {
    (0..n_jobs as u64)
        .map(|j| {
            let base = j * JOB_BLOCK_STRIDE;
            let inputs = (base..base + source_blocks as u64).map(BlockId).collect();
            chain(j, &[App::Sort, App::Join, App::Aggregation], inputs)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_levels_are_sequential() {
        let job = chain(0, &[App::Sort, App::Join, App::Grep], vec![BlockId(0), BlockId(1)]);
        assert_eq!(job.n_stages(), 3);
        assert_eq!(job.levels(), vec![0, 1, 2]);
        assert_eq!(job.sinks(), vec![2]);
        assert!(job.has_consumer(0));
        assert!(job.has_consumer(1));
        assert!(!job.has_consumer(2));
    }

    #[test]
    fn diamond_shape() {
        let job = diamond(
            1,
            App::Sort,
            (App::Grep, App::Aggregation),
            App::Aggregation,
            vec![BlockId(0)],
            vec![BlockId(10), BlockId(11)],
        );
        assert_eq!(job.levels(), vec![0, 1, 1, 2]);
        assert_eq!(job.sinks(), vec![3]);
        // Branch scans ride along as fresh inputs.
        assert_eq!(job.input_blocks(), vec![BlockId(0), BlockId(10), BlockId(11)]);
    }

    #[test]
    fn fan_in_shape() {
        let job = fan_in(
            2,
            vec![(App::Sort, vec![BlockId(0)]), (App::Grep, vec![BlockId(1)])],
            App::Join,
        );
        assert_eq!(job.levels(), vec![0, 0, 1]);
        assert_eq!(job.sinks(), vec![2]);
    }

    #[test]
    #[should_panic(expected = "reads nothing")]
    fn stage_without_inputs_rejected() {
        DagJob::new(
            0,
            vec![DagStage { app: App::Sort, deps: Vec::new(), input_blocks: Vec::new() }],
        );
    }

    #[test]
    #[should_panic(expected = "not an earlier stage")]
    fn forward_dep_rejected() {
        DagJob::new(
            0,
            vec![
                DagStage { app: App::Sort, deps: vec![1], input_blocks: Vec::new() },
                DagStage { app: App::Grep, deps: Vec::new(), input_blocks: vec![BlockId(0)] },
            ],
        );
    }

    #[test]
    fn cost_model_tracks_volume_and_app() {
        // Sort shuffles everything: output = input, and losing it costs
        // map + full reduce CPU.
        assert_eq!(stage_output_bytes(App::Sort, 512 * MB), 512 * MB);
        // Grep's output is tiny but never zero.
        assert!(stage_output_bytes(App::Grep, 512 * MB) < 16 * MB);
        assert!(stage_output_bytes(App::Grep, 1) >= 1);
        // Cost grows linearly with input volume.
        let c1 = stage_recompute_cost_s(App::Sort, 128 * MB);
        let c4 = stage_recompute_cost_s(App::Sort, 512 * MB);
        assert!((c4 / c1 - 4.0).abs() < 1e-9);
        // Sort's full-volume shuffle makes its outputs pricier per input
        // byte than Grep's.
        assert!(c1 > stage_recompute_cost_s(App::Grep, 128 * MB));
    }

    #[test]
    fn suites_use_disjoint_block_ranges() {
        let jobs = diamond_suite(3, 4, 8);
        assert_eq!(jobs.len(), 3);
        let mut all: Vec<BlockId> = jobs.iter().flat_map(|j| j.input_blocks()).collect();
        let n = all.len();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), n, "suite jobs must not share blocks");
        for job in &jobs {
            assert_eq!(job.levels(), vec![0, 1, 1, 2]);
        }
        let chains = chain_suite(2, 4);
        assert_eq!(chains.len(), 2);
        assert_eq!(chains[0].levels(), vec![0, 1, 2]);
    }
}
