//! Typed configuration for the simulated cluster and experiments.
//!
//! Defaults mirror the paper's testbed (§6.1, Table 6): 1 NameNode +
//! 9 DataNodes on 10 GbE, HDD storage, 1.5 GB cache per DataNode,
//! replication 3, 64/128 MB blocks, speculative execution off.
//! Values can be overridden from a TOML-subset file (`config::toml`) or CLI
//! flags; every field is validated before a simulation starts.

pub mod toml;

use anyhow::{bail, Context, Result};

use crate::util::bytes::{self, GB, MB};

/// Disk (HDD) service model for a DataNode.
#[derive(Debug, Clone, PartialEq)]
pub struct DiskModel {
    /// Sequential read bandwidth in bytes/sec (paper: 1 TB HDD, ~120 MB/s).
    pub read_bandwidth_bps: f64,
    /// Per-request positioning latency in seconds (seek + rotational).
    pub seek_latency_s: f64,
}

impl Default for DiskModel {
    fn default() -> Self {
        DiskModel { read_bandwidth_bps: 120.0 * MB as f64, seek_latency_s: 0.008 }
    }
}

/// Network model between nodes in the same rack (paper: 10 GbE switch).
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkModel {
    pub bandwidth_bps: f64,
    pub rtt_s: f64,
}

impl Default for NetworkModel {
    fn default() -> Self {
        NetworkModel { bandwidth_bps: 1.25 * GB as f64, rtt_s: 0.0002 }
    }
}

/// Memory (off-heap cache) read model.
#[derive(Debug, Clone, PartialEq)]
pub struct MemoryModel {
    pub read_bandwidth_bps: f64,
    pub access_latency_s: f64,
}

impl Default for MemoryModel {
    fn default() -> Self {
        MemoryModel { read_bandwidth_bps: 8.0 * GB as f64, access_latency_s: 0.000_05 }
    }
}

/// Whole-cluster configuration (Table 6 defaults).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Number of DataNodes (paper: 9, plus one NameNode).
    pub datanodes: usize,
    /// dfs.replication (paper: 3).
    pub replication: usize,
    /// dfs.blocksize in bytes (paper: 64 MB or 128 MB).
    pub block_size: u64,
    /// Off-heap cache capacity per DataNode in bytes (paper: 1.5 GB).
    pub cache_capacity_per_node: u64,
    /// Independently locked cache shards per DataNode (1 = the paper's
    /// single LRU stack; more enables concurrent shard replay).
    pub cache_shards: usize,
    /// Insert-time admission policy in front of every shard's replacement
    /// policy: "always" (default, the paper's behaviour), "tinylfu",
    /// "ghost" or "svm" (see `cache::admission`).
    pub cache_admission: String,
    /// Cold SVM queries buffered per prediction-batcher shard before a
    /// flush is forced (see `coordinator::batcher::BatcherConfig`). 1 =
    /// flush every cold query synchronously (the legacy behaviour);
    /// larger values defer cold predictions to amortize backend calls.
    pub cache_batch_queue: usize,
    /// Flush deadline of the cold-query queue in **simulated**
    /// milliseconds (request-clock time, so seeded runs stay
    /// deterministic): the oldest deferred query never waits longer than
    /// this for its batch.
    pub cache_batch_deadline_ms: u64,
    /// Lock-free-hit recency updates buffered per replay worker before a
    /// batched drain under the shard lock (see `cache::read_path`). 1 =
    /// drain every hit immediately (the legacy locked-hit behaviour).
    pub cache_recency_batch: usize,
    /// Cadence drain of the recency buffers in **simulated** milliseconds
    /// (request-clock time, deterministic): a non-empty buffer older than
    /// this drains on the next access. 0 disables the cadence (drains are
    /// fill- and mutation-driven only).
    pub cache_recency_drain_cadence_ms: u64,
    /// Map container memory (mapreduce.map.memory.mb) — bounds map slots.
    pub map_memory_mb: u64,
    /// Reduce container memory (mapreduce.reduce.memory.mb).
    pub reduce_memory_mb: u64,
    /// Physical memory per node available to containers.
    pub node_memory_mb: u64,
    /// CPU cores per node (i7-6700: 4 cores / 8 threads).
    pub cores_per_node: usize,
    /// DataNode heartbeat (and cache report) interval in seconds.
    pub heartbeat_interval_s: f64,
    /// Speculative execution (paper disables it).
    pub speculative_execution: bool,
    pub disk: DiskModel,
    pub network: NetworkModel,
    pub memory: MemoryModel,
    /// RNG seed for the whole simulation.
    pub seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            datanodes: 9,
            replication: 3,
            block_size: 128 * MB,
            cache_capacity_per_node: (1.5 * GB as f64) as u64,
            cache_shards: 1,
            cache_admission: "always".into(),
            cache_batch_queue: 1,
            cache_batch_deadline_ms: 2,
            cache_recency_batch: 1,
            cache_recency_drain_cadence_ms: 0,
            map_memory_mb: 1024,
            reduce_memory_mb: 2048,
            node_memory_mb: 16 * 1024,
            cores_per_node: 4,
            heartbeat_interval_s: 3.0,
            speculative_execution: false,
            disk: DiskModel::default(),
            network: NetworkModel::default(),
            memory: MemoryModel::default(),
            seed: 20230101,
        }
    }
}

impl ClusterConfig {
    /// Map task slots per node, bounded by container memory and cores.
    pub fn map_slots_per_node(&self) -> usize {
        let by_mem = (self.node_memory_mb / self.map_memory_mb.max(1)) as usize;
        by_mem.min(self.cores_per_node * 2).max(1)
    }

    /// Reduce task slots per node.
    pub fn reduce_slots_per_node(&self) -> usize {
        let by_mem = (self.node_memory_mb / self.reduce_memory_mb.max(1)) as usize;
        by_mem.min(self.cores_per_node).max(1)
    }

    /// Cache capacity per node measured in whole blocks.
    pub fn cache_blocks_per_node(&self) -> u64 {
        self.cache_capacity_per_node / self.block_size.max(1)
    }

    /// The recency-batching knobs as a [`crate::cache::RecencyConfig`]
    /// (cadence converted from simulated milliseconds to microseconds).
    pub fn recency_config(&self) -> crate::cache::RecencyConfig {
        crate::cache::RecencyConfig::default()
            .with_batch(self.cache_recency_batch.max(1))
            .with_drain_cadence(crate::sim::SimDuration::from_micros(
                self.cache_recency_drain_cadence_ms.saturating_mul(1000),
            ))
    }

    pub fn validate(&self) -> Result<()> {
        if self.datanodes == 0 {
            bail!("datanodes must be > 0");
        }
        if self.replication == 0 || self.replication > self.datanodes {
            bail!(
                "replication {} must be in 1..={}",
                self.replication,
                self.datanodes
            );
        }
        if self.block_size == 0 {
            bail!("block_size must be > 0");
        }
        if self.cache_shards == 0 {
            bail!("cache_shards must be > 0");
        }
        if crate::cache::admission::make_admission(&self.cache_admission).is_none() {
            bail!(
                "cache admission must be one of {:?}, got {:?}",
                crate::cache::admission::ADMISSION_NAMES,
                self.cache_admission
            );
        }
        if self.cache_batch_queue == 0 {
            bail!("cache_batch_queue must be > 0");
        }
        if self.cache_recency_batch == 0 {
            bail!("cache_recency_batch must be > 0");
        }
        if self.disk.read_bandwidth_bps <= 0.0
            || self.network.bandwidth_bps <= 0.0
            || self.memory.read_bandwidth_bps <= 0.0
        {
            bail!("bandwidths must be positive");
        }
        if self.heartbeat_interval_s <= 0.0 {
            bail!("heartbeat interval must be positive");
        }
        Ok(())
    }

    /// Apply overrides from a parsed TOML document ([cluster] section).
    pub fn apply_toml(&mut self, doc: &toml::Document) -> Result<()> {
        if let Some(v) = doc.get_i64("cluster.datanodes") {
            self.datanodes = v as usize;
        }
        if let Some(v) = doc.get_i64("cluster.replication") {
            self.replication = v as usize;
        }
        if let Some(v) = doc.get_str("cluster.block_size") {
            self.block_size = bytes::parse_bytes(v)
                .with_context(|| format!("bad cluster.block_size {v:?}"))?;
        }
        if let Some(v) = doc.get_str("cluster.cache_capacity_per_node") {
            self.cache_capacity_per_node = bytes::parse_bytes(v)
                .with_context(|| format!("bad cluster.cache_capacity_per_node {v:?}"))?;
        }
        if let Some(v) = doc.get_i64("cluster.cache_shards") {
            if v <= 0 {
                bail!("cluster.cache_shards must be positive, got {v}");
            }
            self.cache_shards = v as usize;
        }
        if let Some(v) = doc.get_str("cluster.admission") {
            self.cache_admission = v.to_string();
        }
        if let Some(v) = doc.get_i64("cluster.cache_batch_queue") {
            if v <= 0 {
                bail!("cluster.cache_batch_queue must be positive, got {v}");
            }
            self.cache_batch_queue = v as usize;
        }
        if let Some(v) = doc.get_i64("cluster.cache_batch_deadline_ms") {
            if v < 0 {
                bail!("cluster.cache_batch_deadline_ms must be >= 0, got {v}");
            }
            self.cache_batch_deadline_ms = v as u64;
        }
        if let Some(v) = doc.get_i64("cluster.cache_recency_batch") {
            if v <= 0 {
                bail!("cluster.cache_recency_batch must be positive, got {v}");
            }
            self.cache_recency_batch = v as usize;
        }
        if let Some(v) = doc.get_i64("cluster.cache_recency_drain_cadence_ms") {
            if v < 0 {
                bail!("cluster.cache_recency_drain_cadence_ms must be >= 0, got {v}");
            }
            self.cache_recency_drain_cadence_ms = v as u64;
        }
        if let Some(v) = doc.get_i64("cluster.map_memory_mb") {
            self.map_memory_mb = v as u64;
        }
        if let Some(v) = doc.get_i64("cluster.reduce_memory_mb") {
            self.reduce_memory_mb = v as u64;
        }
        if let Some(v) = doc.get_i64("cluster.node_memory_mb") {
            self.node_memory_mb = v as u64;
        }
        if let Some(v) = doc.get_i64("cluster.cores_per_node") {
            self.cores_per_node = v as usize;
        }
        if let Some(v) = doc.get_f64("cluster.heartbeat_interval_s") {
            self.heartbeat_interval_s = v;
        }
        if let Some(v) = doc.get_bool("cluster.speculative_execution") {
            self.speculative_execution = v;
        }
        if let Some(v) = doc.get_f64("cluster.disk.read_bandwidth_mbps") {
            self.disk.read_bandwidth_bps = v * MB as f64;
        }
        if let Some(v) = doc.get_f64("cluster.disk.seek_latency_ms") {
            self.disk.seek_latency_s = v / 1000.0;
        }
        if let Some(v) = doc.get_f64("cluster.network.bandwidth_gbps") {
            self.network.bandwidth_bps = v * GB as f64 / 8.0;
        }
        if let Some(v) = doc.get_f64("cluster.memory.read_bandwidth_gbps") {
            self.memory.read_bandwidth_bps = v * GB as f64;
        }
        if let Some(v) = doc.get_i64("cluster.seed") {
            self.seed = v as u64;
        }
        self.validate()
    }
}

/// SVM classifier configuration for the coordinator.
#[derive(Debug, Clone, PartialEq)]
pub struct SvmConfig {
    /// "hlo" (PJRT artifacts) or "rust" (in-process SMO reference).
    pub backend: String,
    /// Directory holding the AOT artifacts.
    pub artifacts_dir: String,
    /// Kernel function variant (linear | rbf | sigmoid).
    pub kernel: String,
    /// Retrain after this many new labeled history samples.
    pub retrain_interval: usize,
    /// Minimum samples before the first training round.
    pub min_train_samples: usize,
}

impl Default for SvmConfig {
    fn default() -> Self {
        SvmConfig {
            backend: "hlo".into(),
            artifacts_dir: "artifacts".into(),
            kernel: "rbf".into(),
            retrain_interval: 128,
            min_train_samples: 32,
        }
    }
}

impl SvmConfig {
    pub fn validate(&self) -> Result<()> {
        if !matches!(self.backend.as_str(), "hlo" | "rust") {
            bail!("svm backend must be 'hlo' or 'rust', got {:?}", self.backend);
        }
        if !matches!(self.kernel.as_str(), "linear" | "rbf" | "sigmoid") {
            bail!("svm kernel must be linear|rbf|sigmoid, got {:?}", self.kernel);
        }
        if self.min_train_samples == 0 {
            bail!("min_train_samples must be > 0");
        }
        Ok(())
    }

    pub fn apply_toml(&mut self, doc: &toml::Document) -> Result<()> {
        if let Some(v) = doc.get_str("svm.backend") {
            self.backend = v.to_string();
        }
        if let Some(v) = doc.get_str("svm.artifacts_dir") {
            self.artifacts_dir = v.to_string();
        }
        if let Some(v) = doc.get_str("svm.kernel") {
            self.kernel = v.to_string();
        }
        if let Some(v) = doc.get_i64("svm.retrain_interval") {
            self.retrain_interval = v as usize;
        }
        if let Some(v) = doc.get_i64("svm.min_train_samples") {
            self.min_train_samples = v as usize;
        }
        self.validate()
    }
}

/// Load both configs from an optional TOML file path.
pub fn load(path: Option<&str>) -> Result<(ClusterConfig, SvmConfig)> {
    let mut cluster = ClusterConfig::default();
    let mut svm = SvmConfig::default();
    if let Some(path) = path {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config file {path:?}"))?;
        let doc = toml::Document::parse(&text)?;
        cluster.apply_toml(&doc)?;
        svm.apply_toml(&doc)?;
    }
    Ok((cluster, svm))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_testbed() {
        let c = ClusterConfig::default();
        assert_eq!(c.datanodes, 9);
        assert_eq!(c.replication, 3);
        assert_eq!(c.block_size, 128 * MB);
        assert_eq!(c.cache_blocks_per_node(), 12); // 1.5GB / 128MB
        assert!(!c.speculative_execution);
        c.validate().unwrap();
    }

    #[test]
    fn cache_blocks_for_64mb() {
        let c = ClusterConfig { block_size: 64 * MB, ..Default::default() };
        assert_eq!(c.cache_blocks_per_node(), 24); // 1.5GB / 64MB
    }

    #[test]
    fn toml_overrides() {
        let doc = toml::Document::parse(
            r#"
[cluster]
datanodes = 4
block_size = "64MB"
cache_capacity_per_node = "768MB"
seed = 7
[cluster.disk]
read_bandwidth_mbps = 90.0
[svm]
backend = "rust"
kernel = "linear"
"#,
        )
        .unwrap();
        let mut c = ClusterConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.datanodes, 4);
        assert_eq!(c.block_size, 64 * MB);
        assert_eq!(c.cache_blocks_per_node(), 12);
        assert_eq!(c.seed, 7);
        assert!((c.disk.read_bandwidth_bps - 90.0 * MB as f64).abs() < 1.0);
        let mut s = SvmConfig::default();
        s.apply_toml(&doc).unwrap();
        assert_eq!(s.backend, "rust");
        assert_eq!(s.kernel, "linear");
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let mut c = ClusterConfig { datanodes: 0, ..Default::default() };
        assert!(c.validate().is_err());
        c.datanodes = 2;
        c.replication = 3;
        assert!(c.validate().is_err());
        let s = SvmConfig { backend: "gpu".into(), ..Default::default() };
        assert!(s.validate().is_err());
        let s = SvmConfig { kernel: "poly".into(), ..Default::default() };
        assert!(s.validate().is_err());
    }

    #[test]
    fn cache_shards_validated_and_overridable() {
        let c = ClusterConfig { cache_shards: 0, ..Default::default() };
        assert!(c.validate().is_err());
        assert_eq!(ClusterConfig::default().cache_shards, 1);
        let doc = toml::Document::parse("[cluster]\ncache_shards = 8").unwrap();
        let mut c = ClusterConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.cache_shards, 8);
        // A negative count must be a config error, not a usize wraparound.
        let doc = toml::Document::parse("[cluster]\ncache_shards = -1").unwrap();
        assert!(ClusterConfig::default().apply_toml(&doc).is_err());
    }

    #[test]
    fn cache_admission_validated_and_overridable() {
        assert_eq!(ClusterConfig::default().cache_admission, "always");
        let c = ClusterConfig { cache_admission: "lfu".into(), ..Default::default() };
        assert!(c.validate().is_err(), "unknown admission must be rejected");
        let doc = toml::Document::parse("[cluster]\nadmission = \"tinylfu\"").unwrap();
        let mut c = ClusterConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.cache_admission, "tinylfu");
        let doc = toml::Document::parse("[cluster]\nadmission = \"nonsense\"").unwrap();
        assert!(ClusterConfig::default().apply_toml(&doc).is_err());
    }

    #[test]
    fn batcher_knobs_validated_and_overridable() {
        let c = ClusterConfig::default();
        assert_eq!(c.cache_batch_queue, 1, "default = legacy synchronous flush");
        assert_eq!(c.cache_batch_deadline_ms, 2);
        let c = ClusterConfig { cache_batch_queue: 0, ..Default::default() };
        assert!(c.validate().is_err());
        let doc = toml::Document::parse(
            "[cluster]\ncache_batch_queue = 16\ncache_batch_deadline_ms = 5",
        )
        .unwrap();
        let mut c = ClusterConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.cache_batch_queue, 16);
        assert_eq!(c.cache_batch_deadline_ms, 5);
        let doc = toml::Document::parse("[cluster]\ncache_batch_queue = -1").unwrap();
        assert!(ClusterConfig::default().apply_toml(&doc).is_err());
        let doc = toml::Document::parse("[cluster]\ncache_batch_deadline_ms = -3").unwrap();
        assert!(ClusterConfig::default().apply_toml(&doc).is_err());
    }

    #[test]
    fn recency_knobs_validated_and_overridable() {
        let c = ClusterConfig::default();
        assert_eq!(c.cache_recency_batch, 1, "default = legacy immediate drain");
        assert_eq!(c.cache_recency_drain_cadence_ms, 0);
        assert!(!c.recency_config().is_buffered(), "defaults are behavior-preserving");
        let c = ClusterConfig { cache_recency_batch: 0, ..Default::default() };
        assert!(c.validate().is_err());
        let doc = toml::Document::parse(
            "[cluster]\ncache_recency_batch = 64\ncache_recency_drain_cadence_ms = 5",
        )
        .unwrap();
        let mut c = ClusterConfig::default();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.cache_recency_batch, 64);
        assert_eq!(c.cache_recency_drain_cadence_ms, 5);
        let rc = c.recency_config();
        assert_eq!(rc.batch, 64);
        assert_eq!(rc.drain_cadence, crate::sim::SimDuration::from_micros(5000));
        let doc = toml::Document::parse("[cluster]\ncache_recency_batch = -1").unwrap();
        assert!(ClusterConfig::default().apply_toml(&doc).is_err());
        let doc =
            toml::Document::parse("[cluster]\ncache_recency_drain_cadence_ms = -3").unwrap();
        assert!(ClusterConfig::default().apply_toml(&doc).is_err());
    }

    #[test]
    fn slots_derived_from_memory() {
        let c = ClusterConfig::default();
        assert_eq!(c.map_slots_per_node(), 8); // min(16G/1G, 2*4cores)
        assert_eq!(c.reduce_slots_per_node(), 4); // min(16G/2G, 4cores)
    }
}
