//! TOML-subset parser (no `serde`/`toml` offline — see DESIGN.md §2).
//!
//! Supports the subset our config files use: `[section]` and
//! `[section.sub]` headers, `key = value` with string / integer / float /
//! boolean / homogeneous-array values, `#` comments, and blank lines.
//! Unsupported TOML (multi-line strings, dates, inline tables) is rejected
//! with a line-numbered error.

use std::collections::BTreeMap;

use thiserror::Error;

/// A parsed TOML-subset value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    String(String),
    Integer(i64),
    Float(f64),
    Boolean(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Integer(i) => Some(*i),
            _ => None,
        }
    }

    /// Integers widen to floats on request.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Integer(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Boolean(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(xs) => Some(xs),
            _ => None,
        }
    }
}

#[derive(Debug, Error)]
pub enum TomlError {
    #[error("line {line}: {msg}")]
    Parse { line: usize, msg: String },
}

fn err(line: usize, msg: impl Into<String>) -> TomlError {
    TomlError::Parse { line, msg: msg.into() }
}

/// Parsed document: dotted-path key -> value ("section.key").
#[derive(Debug, Clone, Default)]
pub struct Document {
    entries: BTreeMap<String, Value>,
}

impl Document {
    pub fn parse(text: &str) -> Result<Self, TomlError> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err(lineno, "unterminated section header"))?
                    .trim();
                if name.is_empty() {
                    return Err(err(lineno, "empty section name"));
                }
                if name.starts_with('[') {
                    return Err(err(lineno, "array-of-tables is not supported"));
                }
                section = name.to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| err(lineno, format!("expected key = value, got {line:?}")))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(err(lineno, "empty key"));
            }
            let value = parse_value(line[eq + 1..].trim(), lineno)?;
            let path = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            if entries.insert(path.clone(), value).is_some() {
                return Err(err(lineno, format!("duplicate key {path:?}")));
            }
        }
        Ok(Document { entries })
    }

    pub fn get(&self, path: &str) -> Option<&Value> {
        self.entries.get(path)
    }

    pub fn get_str(&self, path: &str) -> Option<&str> {
        self.get(path).and_then(Value::as_str)
    }

    pub fn get_i64(&self, path: &str) -> Option<i64> {
        self.get(path).and_then(Value::as_i64)
    }

    pub fn get_f64(&self, path: &str) -> Option<f64> {
        self.get(path).and_then(Value::as_f64)
    }

    pub fn get_bool(&self, path: &str) -> Option<bool> {
        self.get(path).and_then(Value::as_bool)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }

    /// All keys under `section.` (one level or deeper).
    pub fn section_keys<'a>(&'a self, section: &'a str) -> impl Iterator<Item = &'a str> + 'a {
        let prefix = format!("{section}.");
        self.entries
            .keys()
            .filter(move |k| k.starts_with(&prefix))
            .map(String::as_str)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a basic string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str, lineno: usize) -> Result<Value, TomlError> {
    if text.is_empty() {
        return Err(err(lineno, "missing value"));
    }
    if let Some(rest) = text.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err(lineno, "unterminated string"))?;
        if inner.contains('"') {
            return Err(err(lineno, "embedded quotes are not supported"));
        }
        return Ok(Value::String(inner.to_string()));
    }
    if let Some(rest) = text.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| err(lineno, "unterminated array"))?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items = split_array_items(inner, lineno)?
            .into_iter()
            .map(|item| parse_value(item.trim(), lineno))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(Value::Array(items));
    }
    match text {
        "true" => return Ok(Value::Boolean(true)),
        "false" => return Ok(Value::Boolean(false)),
        _ => {}
    }
    if !text.contains('.') && !text.contains('e') && !text.contains('E') {
        if let Ok(i) = text.replace('_', "").parse::<i64>() {
            return Ok(Value::Integer(i));
        }
    }
    if let Ok(f) = text.replace('_', "").parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(lineno, format!("cannot parse value {text:?}")))
}

fn split_array_items(inner: &str, lineno: usize) -> Result<Vec<&str>, TomlError> {
    let mut items = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in inner.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => {
                depth = depth
                    .checked_sub(1)
                    .ok_or_else(|| err(lineno, "unbalanced brackets"))?;
            }
            ',' if !in_str && depth == 0 => {
                items.push(&inner[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if in_str {
        return Err(err(lineno, "unterminated string in array"));
    }
    items.push(&inner[start..]);
    Ok(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = Document::parse(
            r#"
# cluster
top = "level"
[cluster]
datanodes = 9
block_size = "128MB"   # trailing comment
fast = true
ratio = 1.5
sizes = [6, 8, 10]
names = ["a", "b"]
[cluster.disk]
bandwidth = 100.0
"#,
        )
        .unwrap();
        assert_eq!(doc.get_str("top"), Some("level"));
        assert_eq!(doc.get_i64("cluster.datanodes"), Some(9));
        assert_eq!(doc.get_str("cluster.block_size"), Some("128MB"));
        assert_eq!(doc.get_bool("cluster.fast"), Some(true));
        assert_eq!(doc.get_f64("cluster.ratio"), Some(1.5));
        assert_eq!(doc.get_f64("cluster.disk.bandwidth"), Some(100.0));
        let arr = doc.get("cluster.sizes").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].as_i64(), Some(6));
    }

    #[test]
    fn integer_widens_to_float() {
        let doc = Document::parse("x = 3").unwrap();
        assert_eq!(doc.get_f64("x"), Some(3.0));
    }

    #[test]
    fn rejects_duplicates_and_garbage() {
        assert!(Document::parse("a = 1\na = 2").is_err());
        assert!(Document::parse("novalue =").is_err());
        assert!(Document::parse("[unterminated").is_err());
        assert!(Document::parse("x = \"open").is_err());
        assert!(Document::parse("x = [1, 2").is_err());
        assert!(Document::parse("just a line").is_err());
    }

    #[test]
    fn comment_inside_string_is_kept() {
        let doc = Document::parse(r##"x = "a # b""##).unwrap();
        assert_eq!(doc.get_str("x"), Some("a # b"));
    }

    #[test]
    fn section_keys_iterates() {
        let doc = Document::parse("[s]\na = 1\nb = 2\n[t]\nc = 3").unwrap();
        let keys: Vec<_> = doc.section_keys("s").collect();
        assert_eq!(keys, vec!["s.a", "s.b"]);
    }
}
