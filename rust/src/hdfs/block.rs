//! Data blocks and node identifiers.

use std::fmt;

/// A unique HDFS block id.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct BlockId(pub u64);

impl fmt::Display for BlockId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "blk_{}", self.0)
    }
}

/// A DataNode id (the paper's cluster has 9; NameNode is separate).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct DataNodeId(pub u32);

impl fmt::Display for DataNodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "dn{}", self.0)
    }
}

/// Data category of a block — the "type" feature of Table 2: input of a Map
/// task, intermediate (shuffle) data, or output of a Reduce task.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BlockKind {
    Input,
    Intermediate,
    Output,
}

impl BlockKind {
    /// One-hot encoding used in the SVM feature vector.
    pub fn one_hot(self) -> [f32; 3] {
        match self {
            BlockKind::Input => [1.0, 0.0, 0.0],
            BlockKind::Intermediate => [0.0, 1.0, 0.0],
            BlockKind::Output => [0.0, 0.0, 1.0],
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BlockKind::Input => "input",
            BlockKind::Intermediate => "intermediate",
            BlockKind::Output => "output",
        }
    }
}

/// Immutable block descriptor held in NameNode block metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockInfo {
    pub id: BlockId,
    /// Owning file id (see hdfs::file).
    pub file: u64,
    /// Block index within the file.
    pub index: u32,
    pub size: u64,
    pub kind: BlockKind,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        assert_eq!(BlockId(7).to_string(), "blk_7");
        assert_eq!(DataNodeId(3).to_string(), "dn3");
    }

    #[test]
    fn one_hot_is_exclusive() {
        for kind in [BlockKind::Input, BlockKind::Intermediate, BlockKind::Output] {
            let oh = kind.one_hot();
            assert_eq!(oh.iter().sum::<f32>(), 1.0);
        }
        assert_ne!(BlockKind::Input.one_hot(), BlockKind::Output.one_hot());
    }
}
