//! Simulated HDFS with centralized cache management (Hadoop ≥ 2.3 semantics).
//!
//! * `block` / `file` — blocks, files, and the namespace registry.
//! * `topology` — balanced replica placement (single rack, like the paper's
//!   testbed).
//! * `namenode` — block metadata + cache metadata, cache-report
//!   reconciliation; the central decision point the H-SVM-LRU coordinator
//!   plugs into.
//! * `datanode` — replica store + off-heap cache that executes NameNode
//!   cache/uncache commands.
//! * `reader` — service-time model for cache/disk, local/remote reads.

pub mod block;
pub mod datanode;
pub mod file;
pub mod namenode;
pub mod reader;
pub mod topology;

pub use block::{BlockId, BlockInfo, BlockKind, DataNodeId};
pub use datanode::DataNode;
pub use file::{DfsFile, FileRegistry};
pub use namenode::{BlockLocation, NameNode};
pub use reader::{classify, service_time, ReadSource};
pub use topology::Placement;
