//! Replica placement. The paper's cluster is a single rack, so placement is
//! load-balanced random: each block's `replication` replicas go to distinct
//! DataNodes, chosen to even out per-node block counts (HDFS's default
//! balancing behaviour without rack topology).

use crate::util::rng::Pcg64;

use super::block::DataNodeId;

/// Chooses DataNodes for new block replicas.
#[derive(Debug)]
pub struct Placement {
    n_nodes: usize,
    replication: usize,
    /// Blocks placed per node — kept balanced.
    load: Vec<u64>,
    rng: Pcg64,
}

impl Placement {
    pub fn new(n_nodes: usize, replication: usize, rng: Pcg64) -> Self {
        assert!((1..=n_nodes).contains(&replication), "bad replication");
        Placement { n_nodes, replication, load: vec![0; n_nodes], rng }
    }

    /// Pick `replication` distinct DataNodes for one block: the least-loaded
    /// nodes, ties broken randomly (deterministic under the seed).
    pub fn place(&mut self) -> Vec<DataNodeId> {
        let mut order: Vec<usize> = (0..self.n_nodes).collect();
        self.rng.shuffle(&mut order);
        order.sort_by_key(|&i| self.load[i]); // stable sort keeps the shuffle as tiebreak
        order[..self.replication]
            .iter()
            .map(|&i| {
                self.load[i] += 1;
                DataNodeId(i as u32)
            })
            .collect()
    }

    pub fn per_node_load(&self) -> &[u64] {
        &self.load
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replicas_are_distinct() {
        let mut p = Placement::new(9, 3, Pcg64::new(1, 0));
        for _ in 0..100 {
            let nodes = p.place();
            assert_eq!(nodes.len(), 3);
            let mut uniq = nodes.clone();
            uniq.sort();
            uniq.dedup();
            assert_eq!(uniq.len(), 3);
        }
    }

    #[test]
    fn load_stays_balanced() {
        let mut p = Placement::new(9, 3, Pcg64::new(2, 0));
        for _ in 0..300 {
            p.place();
        }
        let load = p.per_node_load();
        let min = *load.iter().min().unwrap();
        let max = *load.iter().max().unwrap();
        assert!(max - min <= 1, "unbalanced: {load:?}");
    }

    #[test]
    #[should_panic(expected = "bad replication")]
    fn replication_larger_than_cluster_panics() {
        Placement::new(2, 3, Pcg64::new(0, 0));
    }
}
