//! Block read cost model: where a read is served from determines its
//! service time. This is the I/O half of the paper's execution-time claim —
//! cache reads at memory bandwidth vs disk reads at HDD bandwidth (plus a
//! network hop when the reader's container is not co-located with the data).

use crate::config::ClusterConfig;
use crate::sim::SimDuration;

use super::block::DataNodeId;
use super::namenode::BlockLocation;

/// Source a block read was served from (metrics dimension).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReadSource {
    CacheLocal,
    CacheRemote,
    DiskLocal,
    DiskRemote,
}

impl ReadSource {
    pub fn is_cache(self) -> bool {
        matches!(self, ReadSource::CacheLocal | ReadSource::CacheRemote)
    }
}

/// Classify a resolved location relative to the task's node.
pub fn classify(location: BlockLocation, reader_node: DataNodeId) -> (ReadSource, DataNodeId) {
    match location {
        BlockLocation::Cached(dn) if dn == reader_node => (ReadSource::CacheLocal, dn),
        BlockLocation::Cached(dn) => (ReadSource::CacheRemote, dn),
        BlockLocation::OnDisk(dn) if dn == reader_node => (ReadSource::DiskLocal, dn),
        BlockLocation::OnDisk(dn) => (ReadSource::DiskRemote, dn),
    }
}

/// Pure service-time of reading `size` bytes from `source` (excluding
/// queueing, which the DataNode's `Resource`s add).
pub fn service_time(cfg: &ClusterConfig, source: ReadSource, size: u64) -> SimDuration {
    let transfer = |bw_bps: f64| size as f64 / bw_bps;
    let seconds = match source {
        ReadSource::CacheLocal => cfg.memory.access_latency_s + transfer(cfg.memory.read_bandwidth_bps),
        ReadSource::CacheRemote => {
            // memory read on the remote node + network transfer
            cfg.memory.access_latency_s
                + transfer(cfg.memory.read_bandwidth_bps)
                + cfg.network.rtt_s
                + transfer(cfg.network.bandwidth_bps)
        }
        ReadSource::DiskLocal => cfg.disk.seek_latency_s + transfer(cfg.disk.read_bandwidth_bps),
        ReadSource::DiskRemote => {
            cfg.disk.seek_latency_s
                + transfer(cfg.disk.read_bandwidth_bps)
                + cfg.network.rtt_s
                + transfer(cfg.network.bandwidth_bps)
        }
    };
    SimDuration::from_secs_f64(seconds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdfs::block::DataNodeId;
    use crate::util::bytes::MB;

    #[test]
    fn classify_matrix() {
        let me = DataNodeId(1);
        let other = DataNodeId(2);
        assert_eq!(
            classify(BlockLocation::Cached(me), me).0,
            ReadSource::CacheLocal
        );
        assert_eq!(
            classify(BlockLocation::Cached(other), me).0,
            ReadSource::CacheRemote
        );
        assert_eq!(
            classify(BlockLocation::OnDisk(me), me).0,
            ReadSource::DiskLocal
        );
        assert_eq!(
            classify(BlockLocation::OnDisk(other), me).0,
            ReadSource::DiskRemote
        );
    }

    #[test]
    fn cache_reads_are_much_faster_than_disk() {
        let cfg = ClusterConfig::default();
        let size = 128 * MB;
        let cache = service_time(&cfg, ReadSource::CacheLocal, size);
        let disk = service_time(&cfg, ReadSource::DiskLocal, size);
        assert!(
            disk.as_secs_f64() / cache.as_secs_f64() > 10.0,
            "disk {disk} should dwarf cache {cache}"
        );
    }

    #[test]
    fn remote_adds_network_cost() {
        let cfg = ClusterConfig::default();
        let size = 128 * MB;
        let local = service_time(&cfg, ReadSource::CacheLocal, size);
        let remote = service_time(&cfg, ReadSource::CacheRemote, size);
        assert!(remote > local);
        let expected_extra = cfg.network.rtt_s + size as f64 / cfg.network.bandwidth_bps;
        let got_extra = remote.as_secs_f64() - local.as_secs_f64();
        assert!((got_extra - expected_extra).abs() < 1e-6);
    }

    #[test]
    fn is_cache_flag() {
        assert!(ReadSource::CacheLocal.is_cache());
        assert!(ReadSource::CacheRemote.is_cache());
        assert!(!ReadSource::DiskLocal.is_cache());
        assert!(!ReadSource::DiskRemote.is_cache());
    }
}
