//! DataNode: local block storage plus an off-heap block cache.
//!
//! The DataNode executes cache/uncache commands piggybacked on heartbeats
//! (per the paper's §2: the NameNode manages DataNode caches centrally) and
//! reports its cached blocks back with a periodic *cache report*.

use std::collections::{BTreeSet, HashMap};

use crate::sim::Resource;

use super::block::{BlockId, DataNodeId};

/// Off-heap cache state on one DataNode.
#[derive(Debug)]
pub struct DataNode {
    pub id: DataNodeId,
    /// Blocks stored on local disk (replica placement).
    stored: BTreeSet<BlockId>,
    /// Blocks currently in the off-heap cache, with their sizes.
    cached: HashMap<BlockId, u64>,
    cache_used: u64,
    cache_capacity: u64,
    /// Disk service queue (one spindle).
    pub disk: Resource,
    /// NIC service queue.
    pub nic: Resource,
}

impl DataNode {
    pub fn new(id: DataNodeId, cache_capacity: u64) -> Self {
        DataNode {
            id,
            stored: BTreeSet::new(),
            cached: HashMap::new(),
            cache_used: 0,
            cache_capacity,
            disk: Resource::new(format!("{id}/disk"), 1),
            nic: Resource::new(format!("{id}/nic"), 1),
        }
    }

    // ---- replica storage ----

    pub fn store_block(&mut self, block: BlockId) {
        self.stored.insert(block);
    }

    pub fn has_block(&self, block: BlockId) -> bool {
        self.stored.contains(&block)
    }

    pub fn n_stored(&self) -> usize {
        self.stored.len()
    }

    // ---- off-heap cache ----

    pub fn cache_capacity(&self) -> u64 {
        self.cache_capacity
    }

    pub fn cache_used(&self) -> u64 {
        self.cache_used
    }

    pub fn cache_free(&self) -> u64 {
        self.cache_capacity - self.cache_used
    }

    pub fn is_cached(&self, block: BlockId) -> bool {
        self.cached.contains_key(&block)
    }

    pub fn n_cached(&self) -> usize {
        self.cached.len()
    }

    /// Execute a cache command. Fails (returns false) if the block is not
    /// stored locally or space is insufficient — the NameNode must evict
    /// first; the DataNode never chooses victims itself.
    pub fn cache_block(&mut self, block: BlockId, size: u64) -> bool {
        if !self.stored.contains(&block) || self.cached.contains_key(&block) {
            return false;
        }
        if size > self.cache_free() {
            return false;
        }
        self.cached.insert(block, size);
        self.cache_used += size;
        true
    }

    /// Execute an uncache command. Returns the freed size.
    pub fn uncache_block(&mut self, block: BlockId) -> Option<u64> {
        let size = self.cached.remove(&block)?;
        self.cache_used -= size;
        Some(size)
    }

    /// The periodic cache report: all blocks cached on this DataNode.
    pub fn cache_report(&self) -> Vec<BlockId> {
        let mut blocks: Vec<BlockId> = self.cached.keys().copied().collect();
        blocks.sort_unstable();
        blocks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::MB;

    fn dn() -> DataNode {
        let mut d = DataNode::new(DataNodeId(0), 256 * MB);
        for i in 0..8 {
            d.store_block(BlockId(i));
        }
        d
    }

    #[test]
    fn cache_respects_capacity() {
        let mut d = dn();
        assert!(d.cache_block(BlockId(0), 128 * MB));
        assert!(d.cache_block(BlockId(1), 128 * MB));
        assert!(!d.cache_block(BlockId(2), MB), "full cache must reject");
        assert_eq!(d.cache_used(), 256 * MB);
        assert_eq!(d.cache_free(), 0);
    }

    #[test]
    fn cannot_cache_foreign_or_duplicate_blocks() {
        let mut d = dn();
        assert!(!d.cache_block(BlockId(99), MB), "not stored locally");
        assert!(d.cache_block(BlockId(3), MB));
        assert!(!d.cache_block(BlockId(3), MB), "already cached");
    }

    #[test]
    fn uncache_frees_space() {
        let mut d = dn();
        d.cache_block(BlockId(0), 100 * MB);
        assert_eq!(d.uncache_block(BlockId(0)), Some(100 * MB));
        assert_eq!(d.uncache_block(BlockId(0)), None);
        assert_eq!(d.cache_used(), 0);
    }

    #[test]
    fn cache_report_lists_cached_blocks() {
        let mut d = dn();
        d.cache_block(BlockId(4), MB);
        d.cache_block(BlockId(2), MB);
        assert_eq!(d.cache_report(), vec![BlockId(2), BlockId(4)]);
    }
}
