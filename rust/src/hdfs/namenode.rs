//! NameNode: block metadata + cache metadata, exactly the two maps the paper
//! describes (§4.1): *block metadata* maps a block to the DataNodes holding
//! replicas; *cache metadata* maps a block to the DataNode caching it.
//!
//! The NameNode is the single decision point for caching (centralized cache
//! management): DataNodes only execute cache/uncache commands and confirm via
//! cache reports.

use std::collections::HashMap;

use super::block::{BlockId, BlockInfo, DataNodeId};
use super::datanode::DataNode;
use super::file::FileRegistry;
use super::topology::Placement;
use crate::util::rng::Pcg64;

/// Where a block can be served from, as resolved by the NameNode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockLocation {
    /// Cache hit: block cached on this DataNode.
    Cached(DataNodeId),
    /// Cache miss: replica on disk of this DataNode (first replica per §4.1).
    OnDisk(DataNodeId),
}

/// The NameNode.
#[derive(Debug)]
pub struct NameNode {
    pub files: FileRegistry,
    /// block metadata: replicas per block.
    replicas: HashMap<BlockId, Vec<DataNodeId>>,
    /// cache metadata: caching DataNode per block.
    cache_map: HashMap<BlockId, DataNodeId>,
    placement: Placement,
}

impl NameNode {
    pub fn new(n_datanodes: usize, replication: usize, rng: Pcg64) -> Self {
        NameNode {
            files: FileRegistry::new(),
            replicas: HashMap::new(),
            cache_map: HashMap::new(),
            placement: Placement::new(n_datanodes, replication, rng),
        }
    }

    /// Register a new file: split into blocks and place replicas on
    /// datanodes (also updates the DataNode stores).
    pub fn register_file(
        &mut self,
        name: impl Into<String>,
        size: u64,
        block_size: u64,
        kind: super::block::BlockKind,
        datanodes: &mut [DataNode],
    ) -> u64 {
        let fid = self.files.create_file(name, size, block_size, kind);
        let blocks: Vec<BlockId> = self.files.blocks_of(fid).to_vec();
        for bid in blocks {
            let nodes = self.placement.place();
            for dn in &nodes {
                datanodes[dn.0 as usize].store_block(bid);
            }
            self.replicas.insert(bid, nodes);
        }
        fid
    }

    pub fn block_info(&self, id: BlockId) -> Option<&BlockInfo> {
        self.files.block(id)
    }

    /// Resolve a block per the paper's query flow: cache metadata first,
    /// then the *first* replica from block metadata ("we choose the first
    /// one to reduce search time").
    pub fn locate(&self, block: BlockId) -> Option<BlockLocation> {
        if let Some(&dn) = self.cache_map.get(&block) {
            return Some(BlockLocation::Cached(dn));
        }
        self.replicas
            .get(&block)
            .and_then(|r| r.first())
            .map(|&dn| BlockLocation::OnDisk(dn))
    }

    pub fn replicas_of(&self, block: BlockId) -> &[DataNodeId] {
        self.replicas.get(&block).map(Vec::as_slice).unwrap_or(&[])
    }

    pub fn is_cached(&self, block: BlockId) -> bool {
        self.cache_map.contains_key(&block)
    }

    pub fn cached_on(&self, block: BlockId) -> Option<DataNodeId> {
        self.cache_map.get(&block).copied()
    }

    pub fn n_cached(&self) -> usize {
        self.cache_map.len()
    }

    /// Record a successful cache command (NameNode-side metadata update;
    /// confirmed later by the DataNode cache report).
    pub fn note_cached(&mut self, block: BlockId, dn: DataNodeId) {
        self.cache_map.insert(block, dn);
    }

    /// Record an uncache.
    pub fn note_uncached(&mut self, block: BlockId) {
        self.cache_map.remove(&block);
    }

    /// Apply a DataNode cache report: reconcile cache metadata with the
    /// ground truth on that node (handles lost/failed cache commands).
    /// Returns the number of corrections made.
    pub fn apply_cache_report(&mut self, dn: DataNodeId, cached: &[BlockId]) -> usize {
        let mut fixes = 0;
        // Blocks the report says are cached but metadata doesn't know about.
        for &b in cached {
            if self.cache_map.get(&b) != Some(&dn) {
                self.cache_map.insert(b, dn);
                fixes += 1;
            }
        }
        // Blocks metadata attributes to dn that the report no longer lists.
        let stale: Vec<BlockId> = self
            .cache_map
            .iter()
            .filter(|(b, &node)| node == dn && !cached.contains(b))
            .map(|(&b, _)| b)
            .collect();
        for b in stale {
            self.cache_map.remove(&b);
            fixes += 1;
        }
        fixes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdfs::block::BlockKind;
    use crate::util::bytes::MB;

    fn cluster() -> (NameNode, Vec<DataNode>) {
        let nn = NameNode::new(3, 2, Pcg64::new(1, 0));
        let dns = (0..3)
            .map(|i| DataNode::new(DataNodeId(i), 256 * MB))
            .collect();
        (nn, dns)
    }

    #[test]
    fn register_places_replicas() {
        let (mut nn, mut dns) = cluster();
        let fid = nn.register_file("f", 256 * MB, 128 * MB, BlockKind::Input, &mut dns);
        let blocks = nn.files.blocks_of(fid).to_vec();
        assert_eq!(blocks.len(), 2);
        for b in &blocks {
            let reps = nn.replicas_of(*b);
            assert_eq!(reps.len(), 2);
            for dn in reps {
                assert!(dns[dn.0 as usize].has_block(*b));
            }
        }
    }

    #[test]
    fn locate_prefers_cache() {
        let (mut nn, mut dns) = cluster();
        let fid = nn.register_file("f", 128 * MB, 128 * MB, BlockKind::Input, &mut dns);
        let b = nn.files.blocks_of(fid)[0];
        let first_replica = nn.replicas_of(b)[0];
        assert_eq!(nn.locate(b), Some(BlockLocation::OnDisk(first_replica)));
        nn.note_cached(b, first_replica);
        assert_eq!(nn.locate(b), Some(BlockLocation::Cached(first_replica)));
        nn.note_uncached(b);
        assert_eq!(nn.locate(b), Some(BlockLocation::OnDisk(first_replica)));
    }

    #[test]
    fn locate_unknown_block_is_none() {
        let (nn, _) = cluster();
        assert_eq!(nn.locate(BlockId(999)), None);
    }

    #[test]
    fn cache_report_reconciles() {
        let (mut nn, mut dns) = cluster();
        let fid = nn.register_file("f", 384 * MB, 128 * MB, BlockKind::Input, &mut dns);
        let blocks: Vec<BlockId> = nn.files.blocks_of(fid).to_vec();
        let dn = DataNodeId(0);
        // Metadata thinks b0 is cached on dn, but the report lists only b1.
        nn.note_cached(blocks[0], dn);
        let fixes = nn.apply_cache_report(dn, &[blocks[1]]);
        assert_eq!(fixes, 2);
        assert!(!nn.is_cached(blocks[0]));
        assert_eq!(nn.cached_on(blocks[1]), Some(dn));
        // A matching report makes no corrections.
        assert_eq!(nn.apply_cache_report(dn, &[blocks[1]]), 0);
    }
}
