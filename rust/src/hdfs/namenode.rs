//! NameNode: block metadata + cache metadata, exactly the two maps the paper
//! describes (§4.1): *block metadata* maps a block to the DataNodes holding
//! replicas; *cache metadata* maps a block to the DataNode caching it.
//!
//! The NameNode is the single decision point for caching (centralized cache
//! management): DataNodes only execute cache/uncache commands and confirm via
//! cache reports.

use std::collections::{BTreeSet, HashMap};

use super::block::{BlockId, BlockInfo, DataNodeId};
use super::datanode::DataNode;
use super::file::FileRegistry;
use super::topology::Placement;
use crate::util::rng::Pcg64;

/// Where a block can be served from, as resolved by the NameNode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockLocation {
    /// Cache hit: block cached on this DataNode.
    Cached(DataNodeId),
    /// Cache miss: replica on disk of this DataNode (first replica per §4.1).
    OnDisk(DataNodeId),
}

/// The NameNode.
#[derive(Debug)]
pub struct NameNode {
    pub files: FileRegistry,
    /// block metadata: replicas per block.
    replicas: HashMap<BlockId, Vec<DataNodeId>>,
    /// cache metadata: caching DataNode per block.
    cache_map: HashMap<BlockId, DataNodeId>,
    /// Liveness metadata: DataNodes currently marked dead (heartbeat
    /// timeout in real HDFS, scripted [`FaultEvent::NodeDown`]
    /// (`crate::sim::FaultEvent`) here). A `BTreeSet` so iteration order —
    /// and everything derived from it — is deterministic.
    dead: BTreeSet<u32>,
    placement: Placement,
}

impl NameNode {
    pub fn new(n_datanodes: usize, replication: usize, rng: Pcg64) -> Self {
        NameNode {
            files: FileRegistry::new(),
            replicas: HashMap::new(),
            cache_map: HashMap::new(),
            dead: BTreeSet::new(),
            placement: Placement::new(n_datanodes, replication, rng),
        }
    }

    /// Register a new file: split into blocks and place replicas on
    /// datanodes (also updates the DataNode stores).
    pub fn register_file(
        &mut self,
        name: impl Into<String>,
        size: u64,
        block_size: u64,
        kind: super::block::BlockKind,
        datanodes: &mut [DataNode],
    ) -> u64 {
        let fid = self.files.create_file(name, size, block_size, kind);
        let blocks: Vec<BlockId> = self.files.blocks_of(fid).to_vec();
        for bid in blocks {
            let nodes = self.placement.place();
            for dn in &nodes {
                datanodes[dn.0 as usize].store_block(bid);
            }
            self.replicas.insert(bid, nodes);
        }
        fid
    }

    pub fn block_info(&self, id: BlockId) -> Option<&BlockInfo> {
        self.files.block(id)
    }

    /// Resolve a block per the paper's query flow: cache metadata first,
    /// then the *first* replica from block metadata ("we choose the first
    /// one to reduce search time"). Dead-node aware: a cached copy on a
    /// dead node is skipped (falling through to disk replicas), dead
    /// replicas are skipped, and a block whose every replica is dead
    /// resolves to `None` — the caller must recompute or fail the read.
    pub fn locate(&self, block: BlockId) -> Option<BlockLocation> {
        if let Some(&dn) = self.cache_map.get(&block) {
            if !self.dead.contains(&dn.0) {
                return Some(BlockLocation::Cached(dn));
            }
        }
        self.replicas
            .get(&block)
            .and_then(|r| r.iter().find(|dn| !self.dead.contains(&dn.0)))
            .map(|&dn| BlockLocation::OnDisk(dn))
    }

    pub fn replicas_of(&self, block: BlockId) -> &[DataNodeId] {
        self.replicas.get(&block).map(Vec::as_slice).unwrap_or(&[])
    }

    /// The block's replicas on live DataNodes, in placement order.
    pub fn live_replicas_of(&self, block: BlockId) -> Vec<DataNodeId> {
        self.replicas_of(block)
            .iter()
            .copied()
            .filter(|dn| !self.dead.contains(&dn.0))
            .collect()
    }

    /// Mark a DataNode dead (scripted failure / missed heartbeats). Cached
    /// copies on the node are gone with its memory: the cache metadata is
    /// invalidated and the orphaned block ids are returned — sorted, so
    /// callers invalidate their own views in a deterministic order.
    /// Idempotent: re-killing a dead node orphans nothing new.
    pub fn mark_dead(&mut self, dn: DataNodeId) -> Vec<BlockId> {
        if !self.dead.insert(dn.0) {
            return Vec::new();
        }
        let mut orphaned: Vec<BlockId> = self
            .cache_map
            .iter()
            .filter(|(_, &node)| node == dn)
            .map(|(&b, _)| b)
            .collect();
        orphaned.sort_unstable_by_key(|b| b.0);
        for b in &orphaned {
            self.cache_map.remove(b);
        }
        orphaned
    }

    /// Mark a DataNode alive again (recovery). Its disk replicas become
    /// visible to [`locate`](Self::locate) immediately; its cache starts
    /// empty (lost on the way down).
    pub fn mark_alive(&mut self, dn: DataNodeId) {
        self.dead.remove(&dn.0);
    }

    /// Is the DataNode currently marked dead?
    pub fn is_dead(&self, dn: DataNodeId) -> bool {
        self.dead.contains(&dn.0)
    }

    /// DataNodes currently marked dead, ascending.
    pub fn dead_nodes(&self) -> Vec<DataNodeId> {
        self.dead.iter().map(|&n| DataNodeId(n)).collect()
    }

    pub fn is_cached(&self, block: BlockId) -> bool {
        self.cache_map.contains_key(&block)
    }

    pub fn cached_on(&self, block: BlockId) -> Option<DataNodeId> {
        self.cache_map.get(&block).copied()
    }

    pub fn n_cached(&self) -> usize {
        self.cache_map.len()
    }

    /// Record a successful cache command (NameNode-side metadata update;
    /// confirmed later by the DataNode cache report).
    pub fn note_cached(&mut self, block: BlockId, dn: DataNodeId) {
        self.cache_map.insert(block, dn);
    }

    /// Record an uncache.
    pub fn note_uncached(&mut self, block: BlockId) {
        self.cache_map.remove(&block);
    }

    /// Apply a DataNode cache report: reconcile cache metadata with the
    /// ground truth on that node (handles lost/failed cache commands).
    /// Returns the number of corrections made.
    pub fn apply_cache_report(&mut self, dn: DataNodeId, cached: &[BlockId]) -> usize {
        let mut fixes = 0;
        // Blocks the report says are cached but metadata doesn't know about.
        for &b in cached {
            if self.cache_map.get(&b) != Some(&dn) {
                self.cache_map.insert(b, dn);
                fixes += 1;
            }
        }
        // Blocks metadata attributes to dn that the report no longer lists.
        let stale: Vec<BlockId> = self
            .cache_map
            .iter()
            .filter(|(b, &node)| node == dn && !cached.contains(b))
            .map(|(&b, _)| b)
            .collect();
        for b in stale {
            self.cache_map.remove(&b);
            fixes += 1;
        }
        fixes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hdfs::block::BlockKind;
    use crate::util::bytes::MB;

    fn cluster() -> (NameNode, Vec<DataNode>) {
        let nn = NameNode::new(3, 2, Pcg64::new(1, 0));
        let dns = (0..3)
            .map(|i| DataNode::new(DataNodeId(i), 256 * MB))
            .collect();
        (nn, dns)
    }

    #[test]
    fn register_places_replicas() {
        let (mut nn, mut dns) = cluster();
        let fid = nn.register_file("f", 256 * MB, 128 * MB, BlockKind::Input, &mut dns);
        let blocks = nn.files.blocks_of(fid).to_vec();
        assert_eq!(blocks.len(), 2);
        for b in &blocks {
            let reps = nn.replicas_of(*b);
            assert_eq!(reps.len(), 2);
            for dn in reps {
                assert!(dns[dn.0 as usize].has_block(*b));
            }
        }
    }

    #[test]
    fn locate_prefers_cache() {
        let (mut nn, mut dns) = cluster();
        let fid = nn.register_file("f", 128 * MB, 128 * MB, BlockKind::Input, &mut dns);
        let b = nn.files.blocks_of(fid)[0];
        let first_replica = nn.replicas_of(b)[0];
        assert_eq!(nn.locate(b), Some(BlockLocation::OnDisk(first_replica)));
        nn.note_cached(b, first_replica);
        assert_eq!(nn.locate(b), Some(BlockLocation::Cached(first_replica)));
        nn.note_uncached(b);
        assert_eq!(nn.locate(b), Some(BlockLocation::OnDisk(first_replica)));
    }

    #[test]
    fn locate_unknown_block_is_none() {
        let (nn, _) = cluster();
        assert_eq!(nn.locate(BlockId(999)), None);
    }

    #[test]
    fn dead_node_skips_cache_and_replicas() {
        let (mut nn, mut dns) = cluster();
        let fid = nn.register_file("f", 128 * MB, 128 * MB, BlockKind::Input, &mut dns);
        let b = nn.files.blocks_of(fid)[0];
        let reps: Vec<DataNodeId> = nn.replicas_of(b).to_vec();
        assert_eq!(reps.len(), 2);
        nn.note_cached(b, reps[0]);
        // Kill the caching node: its cached copy is orphaned, locate falls
        // through to the surviving disk replica.
        let orphaned = nn.mark_dead(reps[0]);
        assert_eq!(orphaned, vec![b]);
        assert!(nn.is_dead(reps[0]));
        assert!(!nn.is_cached(b), "cache metadata dropped with the node");
        assert_eq!(nn.locate(b), Some(BlockLocation::OnDisk(reps[1])));
        assert_eq!(nn.live_replicas_of(b), vec![reps[1]]);
        // Re-killing is idempotent.
        assert_eq!(nn.mark_dead(reps[0]), Vec::new());
        // Kill the second replica too: the block is unreachable.
        nn.mark_dead(reps[1]);
        assert_eq!(nn.locate(b), None, "all replicas dead");
        assert!(nn.live_replicas_of(b).is_empty());
        // Recovery restores disk visibility (first replica again).
        nn.mark_alive(reps[0]);
        assert_eq!(nn.locate(b), Some(BlockLocation::OnDisk(reps[0])));
        assert_eq!(nn.dead_nodes(), vec![reps[1]]);
    }

    #[test]
    fn cache_report_reconciles() {
        let (mut nn, mut dns) = cluster();
        let fid = nn.register_file("f", 384 * MB, 128 * MB, BlockKind::Input, &mut dns);
        let blocks: Vec<BlockId> = nn.files.blocks_of(fid).to_vec();
        let dn = DataNodeId(0);
        // Metadata thinks b0 is cached on dn, but the report lists only b1.
        nn.note_cached(blocks[0], dn);
        let fixes = nn.apply_cache_report(dn, &[blocks[1]]);
        assert_eq!(fixes, 2);
        assert!(!nn.is_cached(blocks[0]));
        assert_eq!(nn.cached_on(blocks[1]), Some(dn));
        // A matching report makes no corrections.
        assert_eq!(nn.apply_cache_report(dn, &[blocks[1]]), 0);
    }
}
