//! DFS files: named byte ranges split into fixed-size blocks.

use std::collections::BTreeMap;

use super::block::{BlockId, BlockInfo, BlockKind};

/// A file registered in the namespace.
#[derive(Debug, Clone)]
pub struct DfsFile {
    pub id: u64,
    pub name: String,
    pub size: u64,
    pub kind: BlockKind,
    pub blocks: Vec<BlockId>,
}

/// Namespace: files and their block layout. Owned by the NameNode.
#[derive(Debug, Default)]
pub struct FileRegistry {
    next_file: u64,
    next_block: u64,
    files: BTreeMap<u64, DfsFile>,
    blocks: BTreeMap<BlockId, BlockInfo>,
    by_name: BTreeMap<String, u64>,
}

impl FileRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Create a file of `size` bytes split into `block_size` blocks (the
    /// last block may be short). Returns the file id.
    pub fn create_file(
        &mut self,
        name: impl Into<String>,
        size: u64,
        block_size: u64,
        kind: BlockKind,
    ) -> u64 {
        assert!(block_size > 0, "zero block size");
        let name = name.into();
        assert!(
            !self.by_name.contains_key(&name),
            "file {name:?} already exists"
        );
        let id = self.next_file;
        self.next_file += 1;
        let n_blocks = size.div_ceil(block_size).max(1);
        let mut blocks = Vec::with_capacity(n_blocks as usize);
        for i in 0..n_blocks {
            let bid = BlockId(self.next_block);
            self.next_block += 1;
            let bsize = if i == n_blocks - 1 && size % block_size != 0 && size > 0 {
                size % block_size
            } else {
                block_size.min(size.max(1))
            };
            self.blocks.insert(
                bid,
                BlockInfo { id: bid, file: id, index: i as u32, size: bsize, kind },
            );
            blocks.push(bid);
        }
        self.by_name.insert(name.clone(), id);
        self.files.insert(id, DfsFile { id, name, size, kind, blocks });
        id
    }

    pub fn file(&self, id: u64) -> Option<&DfsFile> {
        self.files.get(&id)
    }

    pub fn file_by_name(&self, name: &str) -> Option<&DfsFile> {
        self.by_name.get(name).and_then(|id| self.files.get(id))
    }

    pub fn block(&self, id: BlockId) -> Option<&BlockInfo> {
        self.blocks.get(&id)
    }

    pub fn blocks_of(&self, file: u64) -> &[BlockId] {
        self.files.get(&file).map(|f| f.blocks.as_slice()).unwrap_or(&[])
    }

    pub fn n_files(&self) -> usize {
        self.files.len()
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn iter_blocks(&self) -> impl Iterator<Item = &BlockInfo> {
        self.blocks.values()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::MB;

    #[test]
    fn splits_into_blocks() {
        let mut reg = FileRegistry::new();
        let id = reg.create_file("input.txt", 300 * MB, 128 * MB, BlockKind::Input);
        let f = reg.file(id).unwrap();
        assert_eq!(f.blocks.len(), 3);
        let sizes: Vec<u64> = f.blocks.iter().map(|b| reg.block(*b).unwrap().size).collect();
        assert_eq!(sizes, vec![128 * MB, 128 * MB, 44 * MB]);
        assert_eq!(reg.block(f.blocks[2]).unwrap().index, 2);
    }

    #[test]
    fn exact_multiple_has_full_blocks() {
        let mut reg = FileRegistry::new();
        let id = reg.create_file("x", 256 * MB, 128 * MB, BlockKind::Input);
        let sizes: Vec<u64> = reg.blocks_of(id).iter().map(|b| reg.block(*b).unwrap().size).collect();
        assert_eq!(sizes, vec![128 * MB, 128 * MB]);
    }

    #[test]
    fn tiny_file_gets_one_block() {
        let mut reg = FileRegistry::new();
        let id = reg.create_file("tiny", 5, 128 * MB, BlockKind::Output);
        let blocks = reg.blocks_of(id);
        assert_eq!(blocks.len(), 1);
        assert_eq!(reg.block(blocks[0]).unwrap().size, 5);
    }

    #[test]
    fn lookup_by_name() {
        let mut reg = FileRegistry::new();
        reg.create_file("a", MB, MB, BlockKind::Input);
        reg.create_file("b", MB, MB, BlockKind::Intermediate);
        assert_eq!(reg.file_by_name("b").unwrap().kind, BlockKind::Intermediate);
        assert!(reg.file_by_name("c").is_none());
        assert_eq!(reg.n_files(), 2);
        assert_eq!(reg.n_blocks(), 2);
    }

    #[test]
    #[should_panic(expected = "already exists")]
    fn duplicate_name_panics() {
        let mut reg = FileRegistry::new();
        reg.create_file("a", MB, MB, BlockKind::Input);
        reg.create_file("a", MB, MB, BlockKind::Input);
    }
}
