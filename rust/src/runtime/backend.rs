//! Unified classifier backend: the AOT HLO artifacts via PJRT (production
//! path) or the in-process SMO reference (fallback / cross-validation).

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::config::SvmConfig;
use crate::svm::dataset::{pad, Dataset};
use crate::svm::features::{FeatureVec, N_FEATURES};
use crate::svm::kernel::{KernelKind, KernelParams};
use crate::svm::smo::{self, SmoConfig, SmoModel};

use super::artifacts::{self, Manifest};
use super::pjrt::{F32Input, HloExecutable, PjrtRuntime};

/// A trainable batch classifier (decision scores; class 1 iff score > 0).
///
/// Not `Send`: the PJRT client/executable handles are `Rc`-based in the
/// `xla` crate, and the coordinator is single-threaded by design (the DES
/// owns time).
pub trait SvmBackend {
    fn name(&self) -> &'static str;

    /// (Re)train on a labeled dataset.
    fn train(&mut self, ds: &Dataset) -> Result<()>;

    /// Decision scores for a batch of feature vectors.
    fn decision_batch(&mut self, queries: &[FeatureVec]) -> Result<Vec<f32>>;

    fn is_trained(&self) -> bool;

    /// Export the trained model for immutable snapshot publication
    /// (`coordinator::online`): the returned [`SmoModel`] scores
    /// identically to `decision_batch` but is plain `Send + Sync` data
    /// shard workers can read lock-free behind an `Arc`. Backends whose
    /// state cannot leave the device (the PJRT path keeps dual state in
    /// artifact-shaped buffers) return `None` and online consumers fall
    /// back to the in-process path.
    fn export_model(&self) -> Option<SmoModel> {
        None
    }

    /// Install a previously exported model (snapshot import — the inverse
    /// of [`SvmBackend::export_model`]). Default: unsupported.
    fn import_model(&mut self, _model: SmoModel) -> Result<()> {
        bail!("backend {:?} cannot import model snapshots", self.name())
    }
}

/// Convenience: predicted classes.
pub fn predict_batch(backend: &mut dyn SvmBackend, queries: &[FeatureVec]) -> Result<Vec<bool>> {
    Ok(backend
        .decision_batch(queries)?
        .into_iter()
        .map(|s| s > 0.0)
        .collect())
}

// ---------------------------------------------------------------- HLO path

/// Trained dual state kept on the Rust side between artifact calls.
struct HloModelState {
    x: Vec<f32>,     // [n_train * d]
    y: Vec<f32>,     // [n_train]
    mask: Vec<f32>,  // [n_train]
    alpha: Vec<f32>, // [n_train]
    bias: f32,
}

/// The production backend: `svm_train_<k>.hlo.txt` + `svm_predict_<k>.hlo.txt`
/// compiled once and executed through the PJRT CPU client.
pub struct HloBackend {
    runtime: PjrtRuntime,
    train_exe: HloExecutable,
    predict_exe: HloExecutable,
    manifest: Manifest,
    kind: KernelKind,
    state: Option<HloModelState>,
}

impl HloBackend {
    pub fn load(artifacts_dir: &str, kind: KernelKind) -> Result<Self> {
        let dir = PathBuf::from(artifacts_dir);
        if !artifacts::available(&dir, kind) {
            bail!(
                "artifacts for kernel {:?} not found in {dir:?} — run `make artifacts`",
                kind.name()
            );
        }
        let manifest = Manifest::load(&dir)?;
        manifest.validate()?;
        if !manifest.kernels.iter().any(|k| k == kind.name()) {
            bail!("manifest does not list kernel {:?}", kind.name());
        }
        let runtime = PjrtRuntime::cpu()?;
        let paths = artifacts::paths_for(&dir, kind);
        let train_exe = runtime.load_hlo_text(&paths.train)?;
        let predict_exe = runtime.load_hlo_text(&paths.predict)?;
        log::info!(
            "HLO backend up: kernel={} n_train={} batch={} platform={}",
            kind.name(),
            manifest.n_train,
            manifest.n_predict_batch,
            runtime.platform_name()
        );
        Ok(HloBackend { runtime, train_exe, predict_exe, manifest, kind, state: None })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn kind(&self) -> KernelKind {
        self.kind
    }

    pub fn platform_name(&self) -> String {
        self.runtime.platform_name()
    }
}

impl SvmBackend for HloBackend {
    fn name(&self) -> &'static str {
        "hlo"
    }

    fn train(&mut self, ds: &Dataset) -> Result<()> {
        anyhow::ensure!(!ds.is_empty(), "empty training set");
        let n = self.manifest.n_train;
        // Balanced subsample if the dataset exceeds the artifact capacity.
        let mut rng = crate::util::rng::Pcg64::new(0x7EA1, ds.len() as u64);
        let ds = ds.truncate_balanced(n, &mut rng);
        let p = pad(&ds, n);
        let outputs = self
            .train_exe
            .run_f32(&[
                F32Input { data: &p.x, dims: &[n as i64, N_FEATURES as i64] },
                F32Input { data: &p.y, dims: &[n as i64] },
                F32Input { data: &p.mask, dims: &[n as i64] },
            ])
            .context("running train artifact")?;
        anyhow::ensure!(outputs.len() == 2, "train artifact returned {} outputs", outputs.len());
        let alpha = outputs[0].clone();
        let bias = outputs[1][0];
        anyhow::ensure!(alpha.len() == n, "alpha length mismatch");
        anyhow::ensure!(
            alpha.iter().all(|a| a.is_finite()) && bias.is_finite(),
            "non-finite training result"
        );
        self.state = Some(HloModelState { x: p.x, y: p.y, mask: p.mask, alpha, bias });
        Ok(())
    }

    fn decision_batch(&mut self, queries: &[FeatureVec]) -> Result<Vec<f32>> {
        let state = self.state.as_ref().context("HLO backend not trained")?;
        let b = self.manifest.n_predict_batch;
        let n = self.manifest.n_train;
        let mut scores = Vec::with_capacity(queries.len());
        for chunk in queries.chunks(b) {
            let mut q = vec![0.0f32; b * N_FEATURES];
            for (i, fv) in chunk.iter().enumerate() {
                q[i * N_FEATURES..(i + 1) * N_FEATURES].copy_from_slice(fv);
            }
            let outputs = self
                .predict_exe
                .run_f32(&[
                    F32Input { data: &q, dims: &[b as i64, N_FEATURES as i64] },
                    F32Input { data: &state.x, dims: &[n as i64, N_FEATURES as i64] },
                    F32Input { data: &state.y, dims: &[n as i64] },
                    F32Input { data: &state.alpha, dims: &[n as i64] },
                    F32Input { data: &state.mask, dims: &[n as i64] },
                    F32Input { data: &[state.bias], dims: &[] },
                ])
                .context("running predict artifact")?;
            scores.extend_from_slice(&outputs[0][..chunk.len()]);
        }
        Ok(scores)
    }

    fn is_trained(&self) -> bool {
        self.state.is_some()
    }
}

// --------------------------------------------------------------- Rust path

/// The in-process SMO fallback (`svm.backend = "rust"`).
pub struct RustBackend {
    params: KernelParams,
    cfg: SmoConfig,
    model: Option<SmoModel>,
    /// Cap the training-set size like the HLO path caps at n_train.
    max_train: usize,
}

impl RustBackend {
    pub fn new(kind: KernelKind) -> Self {
        RustBackend {
            params: KernelParams::new(kind),
            cfg: SmoConfig::default(),
            model: None,
            max_train: 256,
        }
    }
}

impl SvmBackend for RustBackend {
    fn name(&self) -> &'static str {
        "rust"
    }

    fn train(&mut self, ds: &Dataset) -> Result<()> {
        anyhow::ensure!(!ds.is_empty(), "empty training set");
        let mut rng = crate::util::rng::Pcg64::new(0x7EA2, ds.len() as u64);
        let ds = ds.truncate_balanced(self.max_train, &mut rng);
        self.model = Some(smo::train(&ds, self.params, &self.cfg));
        Ok(())
    }

    fn decision_batch(&mut self, queries: &[FeatureVec]) -> Result<Vec<f32>> {
        let model = self.model.as_ref().context("Rust backend not trained")?;
        Ok(queries.iter().map(|q| model.decision(q)).collect())
    }

    fn is_trained(&self) -> bool {
        self.model.is_some()
    }

    fn export_model(&self) -> Option<SmoModel> {
        self.model.clone()
    }

    fn import_model(&mut self, model: SmoModel) -> Result<()> {
        self.model = Some(model);
        Ok(())
    }
}

/// Build the configured backend ("hlo" or "rust").
pub fn make_backend(cfg: &SvmConfig) -> Result<Box<dyn SvmBackend>> {
    cfg.validate()?;
    let kind = KernelKind::from_name(&cfg.kernel).context("bad kernel name")?;
    match cfg.backend.as_str() {
        "hlo" => Ok(Box::new(HloBackend::load(&cfg.artifacts_dir, kind)?)),
        "rust" => Ok(Box::new(RustBackend::new(kind))),
        other => bail!("unknown svm backend {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob_dataset(n: usize) -> Dataset {
        let mut rng = crate::util::rng::Pcg64::new(5, 0);
        let mut ds = Dataset::new();
        for _ in 0..n {
            let mut a = [0.0f32; N_FEATURES];
            let mut b = [0.0f32; N_FEATURES];
            for k in 0..N_FEATURES {
                a[k] = rng.gen_normal(0.25, 0.08) as f32;
                b[k] = rng.gen_normal(0.75, 0.08) as f32;
            }
            ds.push(a, true);
            ds.push(b, false);
        }
        ds
    }

    #[test]
    fn rust_backend_trains_and_predicts() {
        let mut be = RustBackend::new(KernelKind::Rbf);
        assert!(!be.is_trained());
        assert!(be.decision_batch(&[[0.5; N_FEATURES]]).is_err());
        let ds = blob_dataset(50);
        be.train(&ds).unwrap();
        assert!(be.is_trained());
        let classes = predict_batch(&mut be, &ds.x).unwrap();
        let acc = classes
            .iter()
            .zip(&ds.y)
            .filter(|(c, &y)| **c == (y > 0.0))
            .count() as f64
            / ds.len() as f64;
        assert!(acc > 0.95, "acc={acc}");
    }

    #[test]
    fn export_import_round_trip_preserves_decisions() {
        let mut trained = RustBackend::new(KernelKind::Rbf);
        assert!(trained.export_model().is_none(), "untrained exports nothing");
        let ds = blob_dataset(40);
        trained.train(&ds).unwrap();
        let model = trained.export_model().expect("trained backend exports");

        let mut imported = RustBackend::new(KernelKind::Rbf);
        imported.import_model(model).unwrap();
        assert!(imported.is_trained());
        let a = trained.decision_batch(&ds.x).unwrap();
        let b = imported.decision_batch(&ds.x).unwrap();
        assert_eq!(a, b, "snapshot round trip must score identically");
    }

    #[test]
    fn make_backend_rejects_bad_config() {
        let cfg = SvmConfig { backend: "gpu".into(), ..Default::default() };
        assert!(make_backend(&cfg).is_err());
        let cfg = SvmConfig {
            backend: "hlo".into(),
            artifacts_dir: "/definitely/missing".into(),
            ..Default::default()
        };
        assert!(make_backend(&cfg).is_err());
    }

    #[test]
    fn rust_backend_via_factory() {
        let cfg = SvmConfig { backend: "rust".into(), ..Default::default() };
        let mut be = make_backend(&cfg).unwrap();
        assert_eq!(be.name(), "rust");
        be.train(&blob_dataset(20)).unwrap();
        assert!(be.is_trained());
    }
}
