//! AOT artifact discovery: locate `artifacts/*.hlo.txt` and parse
//! `manifest.txt` (the key=value file `python -m compile.aot` writes).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::svm::KernelKind;

/// Parsed artifact manifest: the shapes and hyper-parameters baked into
/// the HLO (must match what the Rust side pads/feeds).
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub n_train: usize,
    pub n_features: usize,
    pub n_predict_batch: usize,
    pub c: f32,
    pub gamma: f32,
    pub coef0: f32,
    pub iters: usize,
    pub kernels: Vec<String>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut kv = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("bad manifest line {line:?}"))?;
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        let get = |k: &str| -> Result<&String> {
            kv.get(k).with_context(|| format!("manifest missing key {k:?}"))
        };
        Ok(Manifest {
            n_train: get("n_train")?.parse()?,
            n_features: get("n_features")?.parse()?,
            n_predict_batch: get("n_predict_batch")?.parse()?,
            c: get("c")?.parse()?,
            gamma: get("gamma")?.parse()?,
            coef0: get("coef0")?.parse()?,
            iters: get("iters")?.parse()?,
            kernels: get("kernels")?.split(',').map(str::to_string).collect(),
        })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Manifest::parse(&text)
    }

    /// Validate consistency with the Rust-side constants.
    pub fn validate(&self) -> Result<()> {
        if self.n_features != crate::svm::N_FEATURES {
            bail!(
                "artifact n_features {} != crate N_FEATURES {}",
                self.n_features,
                crate::svm::N_FEATURES
            );
        }
        if self.n_train == 0 || self.n_predict_batch == 0 {
            bail!("degenerate artifact shapes");
        }
        Ok(())
    }
}

/// Paths to one kernel variant's artifacts.
#[derive(Debug, Clone)]
pub struct ArtifactPaths {
    pub train: PathBuf,
    pub predict: PathBuf,
}

/// Resolve the artifact pair for a kernel kind under `dir`.
pub fn paths_for(dir: &Path, kind: KernelKind) -> ArtifactPaths {
    ArtifactPaths {
        train: dir.join(format!("svm_train_{}.hlo.txt", kind.name())),
        predict: dir.join(format!("svm_predict_{}.hlo.txt", kind.name())),
    }
}

/// True when all artifacts for `kind` exist under `dir`.
pub fn available(dir: &Path, kind: KernelKind) -> bool {
    let p = paths_for(dir, kind);
    p.train.exists() && p.predict.exists() && dir.join("manifest.txt").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
n_train=256
n_features=9
n_predict_batch=64
c=4.0
gamma=0.5
coef0=0.0
iters=300
kernels=linear,rbf,sigmoid
";

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.n_train, 256);
        assert_eq!(m.n_features, 9);
        assert_eq!(m.n_predict_batch, 64);
        assert_eq!(m.gamma, 0.5);
        assert_eq!(m.kernels, vec!["linear", "rbf", "sigmoid"]);
        m.validate().unwrap();
    }

    #[test]
    fn missing_key_errors() {
        assert!(Manifest::parse("n_train=4").is_err());
    }

    #[test]
    fn wrong_feature_count_fails_validation() {
        let text = SAMPLE.replace("n_features=9", "n_features=5");
        let m = Manifest::parse(&text).unwrap();
        assert!(m.validate().is_err());
    }

    #[test]
    fn path_layout() {
        let p = paths_for(Path::new("artifacts"), KernelKind::Rbf);
        assert!(p.train.ends_with("svm_train_rbf.hlo.txt"));
        assert!(p.predict.ends_with("svm_predict_rbf.hlo.txt"));
        assert!(!available(Path::new("/nonexistent"), KernelKind::Rbf));
    }
}
