//! Runtime: executes the AOT-compiled JAX/Pallas SVM from the Rust request
//! path through the PJRT C API (`xla` crate).
//!
//! * `pjrt` — client + executable wrappers (HLO text -> compile -> run).
//! * `artifacts` — artifact discovery and manifest validation.
//! * `backend` — the `SvmBackend` abstraction: `hlo` (production) or
//!   `rust` (in-process SMO fallback and numerics cross-check).
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! only consumer of its outputs.

pub mod artifacts;
pub mod backend;
pub mod pjrt;

pub use artifacts::Manifest;
pub use backend::{make_backend, predict_batch, HloBackend, RustBackend, SvmBackend};
pub use pjrt::{F32Input, HloExecutable, PjrtRuntime};
