//! PJRT executor: load HLO-text artifacts, compile once per process, run
//! from the request path.
//!
//! Follows the reference wiring in /opt/xla-example/load_hlo: text (not
//! serialized proto) is the interchange format because jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the HLO text
//! parser reassigns ids.
//!
//! The real client lives behind the `pjrt` cargo feature because the `xla`
//! crate is not in the offline registry (see rust/Cargo.toml). Without the
//! feature this module compiles a stub with the identical API whose
//! constructors return a descriptive error, so `HloBackend::load` fails
//! cleanly and callers fall back to `--svm-backend rust`.

/// An f32 input buffer: data plus its logical dims.
#[derive(Debug, Clone)]
pub struct F32Input<'a> {
    pub data: &'a [f32],
    pub dims: &'a [i64],
}

#[cfg(feature = "pjrt")]
mod imp {
    use std::path::Path;

    use anyhow::{Context, Result};

    use super::F32Input;

    /// A compiled HLO executable plus its client handle.
    pub struct HloExecutable {
        exe: xla::PjRtLoadedExecutable,
        name: String,
    }

    /// Process-wide PJRT CPU client (one per process; executables share it).
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
    }

    impl PjrtRuntime {
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            log::debug!(
                "PJRT client up: platform={} devices={}",
                client.platform_name(),
                client.device_count()
            );
            Ok(PjrtRuntime { client })
        }

        pub fn platform_name(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO text file.
        pub fn load_hlo_text(&self, path: &Path) -> Result<HloExecutable> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {path:?}"))?;
            Ok(HloExecutable {
                exe,
                name: path
                    .file_name()
                    .map(|s| s.to_string_lossy().into_owned())
                    .unwrap_or_default(),
            })
        }
    }

    impl HloExecutable {
        pub fn name(&self) -> &str {
            &self.name
        }

        /// Execute with f32 inputs; returns the flattened f32 outputs of the
        /// result tuple (jax lowering uses return_tuple=True).
        pub fn run_f32(&self, inputs: &[F32Input<'_>]) -> Result<Vec<Vec<f32>>> {
            let mut literals = Vec::with_capacity(inputs.len());
            for inp in inputs {
                let expected: i64 = inp.dims.iter().product();
                anyhow::ensure!(
                    expected == inp.data.len() as i64,
                    "{}: input dims {:?} != data len {}",
                    self.name,
                    inp.dims,
                    inp.data.len()
                );
                let lit = xla::Literal::vec1(inp.data);
                let lit = if inp.dims.len() == 1 {
                    lit
                } else {
                    lit.reshape(inp.dims)
                        .with_context(|| format!("reshape to {:?}", inp.dims))?
                };
                literals.push(lit);
            }
            // Scalars () need an explicit reshape to rank 0.
            for (lit, inp) in literals.iter_mut().zip(inputs) {
                if inp.dims.is_empty() {
                    *lit = lit.reshape(&[]).context("reshape to scalar")?;
                }
            }
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("executing {}", self.name))?;
            let out = result[0][0]
                .to_literal_sync()
                .context("fetching result literal")?;
            let parts = out.to_tuple().context("untupling result")?;
            parts
                .into_iter()
                .map(|lit| lit.to_vec::<f32>().context("reading f32 output"))
                .collect()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use std::path::Path;

    use anyhow::{bail, Result};

    use super::F32Input;

    const UNAVAILABLE: &str = "PJRT support is not compiled in — rebuild with \
         `--features pjrt` (requires the `xla` dependency; see rust/Cargo.toml) \
         or run with `--svm-backend rust`";

    /// Stub standing in for the compiled-HLO executable handle.
    pub struct HloExecutable {
        name: String,
    }

    /// Stub standing in for the process-wide PJRT CPU client.
    pub struct PjrtRuntime {
        _private: (),
    }

    impl PjrtRuntime {
        pub fn cpu() -> Result<Self> {
            bail!(UNAVAILABLE)
        }

        pub fn platform_name(&self) -> String {
            "unavailable".to_string()
        }

        pub fn load_hlo_text(&self, _path: &Path) -> Result<HloExecutable> {
            bail!(UNAVAILABLE)
        }
    }

    impl HloExecutable {
        pub fn name(&self) -> &str {
            &self.name
        }

        pub fn run_f32(&self, _inputs: &[F32Input<'_>]) -> Result<Vec<Vec<f32>>> {
            bail!("{UNAVAILABLE} (executable {:?})", self.name)
        }
    }
}

pub use imp::{HloExecutable, PjrtRuntime};

#[cfg(test)]
mod tests {
    // PJRT-dependent tests live in rust/tests/integration_runtime.rs (they
    // need `make artifacts`). Here we only check input validation logic
    // that doesn't require a client.
    use super::*;

    #[test]
    fn f32input_shape_math() {
        let data = [1.0f32, 2.0, 3.0, 4.0];
        let inp = F32Input { data: &data, dims: &[2, 2] };
        let expected: i64 = inp.dims.iter().product();
        assert_eq!(expected, 4);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_missing_feature() {
        let err = PjrtRuntime::cpu().expect_err("stub must not construct");
        assert!(err.to_string().contains("pjrt"), "{err:#}");
    }
}
