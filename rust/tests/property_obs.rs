//! Property tests for the telemetry layer: histogram merge laws, window
//! rotation determinism across shard counts, audit-ring sampling bounds,
//! confusion-count accounting, and the acceptance criterion that two
//! same-seed observed runs export byte-identical metrics JSONL.

use h_svm_lru::cache::EvictCause;
use h_svm_lru::experiments::sharded_replay::{replay, ReplayOptions, ShardedReplayReport};
use h_svm_lru::hdfs::BlockId;
use h_svm_lru::obs::{
    merge_audits, AuditEntry, EvictionAudit, LogHistogram, MetricsRegistry, ObsConfig,
    RunObservations,
};
use h_svm_lru::sim::SimTime;
use h_svm_lru::svm::features::FeatureVec;
use h_svm_lru::svm::KernelKind;
use h_svm_lru::testkit::{forall, Config, VecU64Gen};
use h_svm_lru::util::bytes::MB;
use h_svm_lru::workload::fig3_trace;

/// Merging per-shard histogram snapshots must be associative and lossless:
/// any grouping of the shards yields the exact totals of one histogram that
/// saw every observation.
#[test]
fn histogram_merge_is_lossless_and_associative() {
    // Values capped well below u64::MAX so the sum cannot overflow.
    let gen = VecU64Gen { min_len: 0, max_len: 400, max_value: 1 << 40 };
    forall(&Config { cases: 60, seed: 0x0B57, ..Default::default() }, &gen, |values| {
        let whole = LogHistogram::new();
        let parts: Vec<LogHistogram> = (0..3).map(|_| LogHistogram::new()).collect();
        for (i, &v) in values.iter().enumerate() {
            whole.record(v);
            parts[i % 3].record(v);
        }

        // ((a + b) + c) vs (a + (b + c)).
        let mut left = parts[0].snapshot();
        left.merge(&parts[1].snapshot());
        left.merge(&parts[2].snapshot());
        let mut bc = parts[1].snapshot();
        bc.merge(&parts[2].snapshot());
        let mut right = parts[0].snapshot();
        right.merge(&bc);

        if left != right {
            return Err("merge is not associative".into());
        }
        if left != whole.snapshot() {
            return Err("merging shard parts loses observations".into());
        }
        if left.count != values.len() as u64 {
            return Err(format!("count {} != {} observations", left.count, values.len()));
        }
        if left.sum != values.iter().sum::<u64>() {
            return Err("sum not preserved across the split".into());
        }
        if left.quantile(0.5) > left.quantile(0.95) {
            return Err("quantiles out of order".into());
        }
        Ok(())
    });
}

fn observed(
    shards: usize,
    cfg: ObsConfig,
) -> (MetricsRegistry, ShardedReplayReport, RunObservations) {
    let trace = fig3_trace(64 * MB, 11);
    let registry = MetricsRegistry::new();
    let out = replay(
        "h-svm-lru",
        shards,
        8 * 64 * MB,
        &trace,
        &ReplayOptions::new().classify(KernelKind::Rbf, 64).observe(&registry, cfg),
    )
    .expect("observed replay");
    let obs = out.observations.expect("observe was configured");
    (registry, out.report, obs)
}

/// The acceptance criterion: two same-seed observed runs must export
/// byte-identical metrics JSONL — at one shard and at eight.
#[test]
fn same_seed_runs_export_byte_identical_jsonl() {
    for shards in [1usize, 8] {
        let render = || {
            let cfg = ObsConfig::default();
            let (registry, report, obs) = observed(shards, cfg);
            let mut doc = obs.into_doc(cfg.window_us);
            doc.meta_str("cmd", "property");
            doc.meta_str("policy", "h-svm-lru");
            doc.meta_u64("shards", shards as u64);
            doc.meta_u64("seed", 11);
            doc.meta_u64("requests", report.stats.requests);
            doc.to_jsonl(&registry)
        };
        let first = render();
        let second = render();
        assert_eq!(first, second, "same-seed JSONL differs at {shards} shard(s)");
        assert!(first.contains("{\"type\":\"meta\""));
        assert!(first.contains("\"type\":\"window\""));
        assert!(first.contains("\"type\":\"audit_meta\""));
        assert!(first.contains("evict.scan_steps"), "deterministic hist must be exported");
        assert!(
            !first.contains("replay.access_ns"),
            "volatile wall-clock hist must stay out of the deterministic export"
        );
    }
}

/// Window rotation is keyed on simulated time only, so per-window request
/// counts cannot depend on how the replay is sharded.
#[test]
fn window_rotation_is_deterministic_across_shard_counts() {
    let cfg = ObsConfig::default();
    let (_, _, one) = observed(1, cfg);
    let (_, _, eight) = observed(8, cfg);
    assert!(!one.windows.is_empty());
    assert_eq!(one.windows.len(), eight.windows.len());
    for ((i1, w1), (i8_, w8)) in one.windows.iter().zip(eight.windows.iter()) {
        assert_eq!(i1, i8_, "window indices diverge across shard counts");
        assert_eq!(
            w1.requests,
            w8.requests,
            "window {i1} request count must not depend on shard count"
        );
    }
    for series in [&one.windows, &eight.windows] {
        assert!(
            series.windows(2).all(|p| p[0].0 < p[1].0),
            "window series must be sorted with unique indices"
        );
    }
}

/// The audit ring records exactly every Nth observed eviction up to its
/// capacity: `sampled == min(cap, ceil(seen / every))`, always the 0th,
/// Nth, 2Nth… entries.
#[test]
fn audit_ring_sampling_respects_every_and_cap() {
    let entry = |i: u64| AuditEntry {
        at: SimTime(i * 10),
        block: BlockId(i),
        cause: EvictCause::Capacity,
        features: FeatureVec::default(),
        score: 0.0,
        predicted: Some(i % 2 == 0),
        actual: i % 3 == 0,
    };
    for every in [1u64, 2, 8, 13] {
        for cap in [1usize, 7, 256] {
            for n in [0u64, 1, 5, 64, 1000] {
                let mut ring = EvictionAudit::new(every, cap);
                for i in 0..n {
                    ring.observe(|| entry(i));
                }
                let (entries, seen) = merge_audits(vec![ring]);
                assert_eq!(seen, n);
                let expect = n.div_ceil(every).min(cap as u64);
                assert_eq!(entries.len() as u64, expect, "every={every} cap={cap} n={n}");
                for (k, e) in entries.iter().enumerate() {
                    assert_eq!(e.block.0, k as u64 * every, "wrong eviction sampled");
                }
            }
        }
    }
}

/// With `audit_every = 1`, one shard, and an over-sized ring, the audit
/// trail captures every eviction — so the windowed confusion counters must
/// tally exactly with a recount over the audit entries.
#[test]
fn confusion_counts_match_a_full_audit_recount() {
    let cfg = ObsConfig { audit_every: 1, audit_cap: 1 << 20, ..ObsConfig::default() };
    let (_, report, obs) = observed(1, cfg);

    let evictions: u64 = obs.windows.iter().map(|(_, w)| w.evictions()).sum();
    assert_eq!(evictions, report.stats.evictions);
    assert_eq!(obs.audit_seen, evictions, "every eviction flows through the ring");
    assert_eq!(obs.audit.len() as u64, evictions, "every=1 + big cap samples all");

    let tp: u64 = obs.windows.iter().map(|(_, w)| w.tp).sum();
    let fp: u64 = obs.windows.iter().map(|(_, w)| w.fp).sum();
    let tn: u64 = obs.windows.iter().map(|(_, w)| w.tn).sum();
    let fn_: u64 = obs.windows.iter().map(|(_, w)| w.fn_).sum();
    let count = |p: Option<bool>, a: bool| {
        obs.audit.iter().filter(|e| e.predicted == p && e.actual == a).count() as u64
    };
    assert_eq!(tp, count(Some(true), true));
    assert_eq!(fp, count(Some(true), false));
    assert_eq!(fn_, count(Some(false), true));
    assert_eq!(tn, count(Some(false), false));

    let labeled: u64 = obs.windows.iter().map(|(_, w)| w.labeled_evictions()).sum();
    assert_eq!(labeled, tp + fp + tn + fn_);
    assert_eq!(labeled, obs.audit.iter().filter(|e| e.predicted.is_some()).count() as u64);
    assert!(labeled <= evictions);
    assert!(labeled > 0, "the classified fig3 trace must label some evictions");
}
