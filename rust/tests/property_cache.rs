//! Property tests over the cache layer: invariants that must hold for
//! every policy on every op sequence.

use h_svm_lru::cache::hsvmlru::HSvmLru;
use h_svm_lru::cache::lru::Lru;
use h_svm_lru::cache::registry::{make_policy, POLICY_NAMES};
use h_svm_lru::cache::{AccessContext, BlockCache};
use h_svm_lru::hdfs::BlockId;
use h_svm_lru::sim::SimTime;
use h_svm_lru::testkit::{forall, CacheOpsGen, Config};

fn ctx(t: u64, reuse: bool) -> AccessContext {
    AccessContext::simple(SimTime(t), 1).with_prediction(reuse)
}

/// Replay ops; check occupancy, accounting and hit+miss bookkeeping.
fn invariants_hold(policy: &str, ops: &[(u64, bool)], capacity: u64) -> Result<(), String> {
    let mut cache = BlockCache::new(make_policy(policy).unwrap(), capacity);
    let mut hits = 0u64;
    let mut misses = 0u64;
    for (t, (key, reuse)) in ops.iter().enumerate() {
        let c = ctx(t as u64, *reuse);
        let before = cache.contains(BlockId(*key));
        let out = cache.access_or_insert(BlockId(*key), &c);
        if out.hit != before {
            return Err(format!("{policy}: hit flag disagrees with contains()"));
        }
        if out.hit {
            hits += 1;
        } else {
            misses += 1;
        }
        if cache.used() > cache.capacity() {
            return Err(format!(
                "{policy}: occupancy {} exceeds capacity {}",
                cache.used(),
                cache.capacity()
            ));
        }
        if cache.used() != cache.len() as u64 {
            return Err(format!("{policy}: byte accounting broken (unit blocks)"));
        }
        for evicted in &out.evicted {
            if cache.contains(*evicted) {
                return Err(format!("{policy}: evicted block {evicted} still cached"));
            }
        }
    }
    if hits + misses != ops.len() as u64 {
        return Err(format!("{policy}: hits+misses != requests"));
    }
    Ok(())
}

#[test]
fn all_policies_uphold_cache_invariants() {
    let gen = CacheOpsGen { max_ops: 300, keyspace: 40, max_capacity: 12 };
    for &policy in POLICY_NAMES {
        forall(&Config { cases: 30, seed: 0xCAFE + policy.len() as u64, ..Default::default() },
               &gen,
               |(ops, cap)| invariants_hold(policy, ops, *cap));
    }
}

#[test]
fn lru_stack_property() {
    // LRU inclusion: a cache of capacity c+1 always contains the cache of
    // capacity c (classic stack property) under the same request stream.
    let gen = CacheOpsGen { max_ops: 200, keyspace: 30, max_capacity: 10 };
    forall(&Config { cases: 40, ..Default::default() }, &gen, |(ops, cap)| {
        let mut small = BlockCache::new(Box::new(Lru::new()), *cap);
        let mut large = BlockCache::new(Box::new(Lru::new()), cap + 1);
        for (t, (key, _)) in ops.iter().enumerate() {
            let c = AccessContext::simple(SimTime(t as u64), 1);
            small.access_or_insert(BlockId(*key), &c);
            large.access_or_insert(BlockId(*key), &c);
            for b in small.cached_blocks() {
                if !large.contains(b) {
                    return Err(format!(
                        "stack property violated: {b} in cap={} but not cap={}",
                        cap,
                        cap + 1
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn hsvmlru_with_all_reused_equals_lru() {
    // The paper's own claim: if every block is classified "reused", the
    // policy is identical to LRU — same hits, same evictions, same order.
    let gen = CacheOpsGen { max_ops: 300, keyspace: 25, max_capacity: 8 };
    forall(&Config { cases: 60, ..Default::default() }, &gen, |(ops, cap)| {
        let mut lru = BlockCache::new(Box::new(Lru::new()), *cap);
        let mut hsvm = BlockCache::new(Box::new(HSvmLru::new()), *cap);
        for (t, (key, _)) in ops.iter().enumerate() {
            let c = ctx(t as u64, true); // all class 1
            let a = lru.access_or_insert(BlockId(*key), &c);
            let b = hsvm.access_or_insert(BlockId(*key), &c);
            if a.hit != b.hit {
                return Err(format!("hit divergence at op {t}"));
            }
            if a.evicted != b.evicted {
                return Err(format!(
                    "eviction divergence at op {t}: lru {:?} vs h-svm-lru {:?}",
                    a.evicted, b.evicted
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn hsvmlru_never_evicts_reused_while_unused_present() {
    let gen = CacheOpsGen { max_ops: 300, keyspace: 40, max_capacity: 10 };
    forall(&Config { cases: 40, ..Default::default() }, &gen, |(ops, cap)| {
        let mut policy = HSvmLru::new();
        let mut cache_members: std::collections::HashMap<BlockId, bool> =
            std::collections::HashMap::new();
        use h_svm_lru::cache::CachePolicy;
        for (t, (key, reuse)) in ops.iter().enumerate() {
            let b = BlockId(*key);
            let c = ctx(t as u64, *reuse);
            if cache_members.contains_key(&b) {
                policy.on_hit(b, &c);
                cache_members.insert(b, *reuse);
            } else {
                if cache_members.len() as u64 >= *cap {
                    let victim = policy.choose_victim(SimTime(t as u64)).unwrap();
                    // Invariant: while any unused-class block is cached, the
                    // victim must be unused-class.
                    let any_unused = cache_members.values().any(|r| !*r);
                    let victim_reused = cache_members[&victim];
                    if any_unused && victim_reused {
                        return Err(format!(
                            "evicted reused block {victim} while unused blocks were cached"
                        ));
                    }
                    policy.on_evict(victim);
                    cache_members.remove(&victim);
                }
                policy.on_insert(b, &c);
                cache_members.insert(b, *reuse);
            }
        }
        Ok(())
    });
}

#[test]
fn eviction_totals_match_insertions() {
    // Conservation: insertions - evictions == final occupancy.
    let gen = CacheOpsGen { max_ops: 400, keyspace: 60, max_capacity: 16 };
    for &policy in POLICY_NAMES {
        forall(&Config { cases: 15, seed: 0xBEEF, ..Default::default() }, &gen, |(ops, cap)| {
            let mut cache = BlockCache::new(make_policy(policy).unwrap(), *cap);
            let mut inserted = 0i64;
            let mut evicted = 0i64;
            for (t, (key, reuse)) in ops.iter().enumerate() {
                let out = cache.access_or_insert(BlockId(*key), &ctx(t as u64, *reuse));
                inserted += (!out.hit && out.inserted) as i64;
                evicted += out.evicted.len() as i64;
            }
            if inserted - evicted != cache.len() as i64 {
                return Err(format!(
                    "{policy}: {inserted} - {evicted} != {}",
                    cache.len()
                ));
            }
            Ok(())
        });
    }
}
