//! Property tests for the online-learning subsystem
//! (`coordinator::online` + `experiments::online_sharded`):
//!
//! * snapshot publish/read race: concurrent readers only ever observe
//!   monotonically non-decreasing versions, and every observed snapshot
//!   is internally consistent (version ↔ model);
//! * online-vs-frozen parity when the trainer never publishes (a
//!   single-class trace): both arms are bit-identical to the
//!   classify-once replay;
//! * `ShardStats` merge correctness on the `insert` path, including
//!   admission-rejected inserts counted as missed requests
//!   (cache/sharded.rs `insert` accounting), driven concurrently.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier};

use h_svm_lru::cache::sharded::{shard_of, ShardStats};
use h_svm_lru::cache::{AccessContext, CacheAffinity, CacheBuilder, RecencyConfig};
use h_svm_lru::coordinator::batcher::BatcherConfig;
use h_svm_lru::coordinator::online::{SnapshotCell, SnapshotReader, TrainerConfig};
use h_svm_lru::experiments::online_sharded::{run_online, TrainerMode as Mode};
use h_svm_lru::experiments::sharded_replay::{classify_trace, replay, ReplayOptions};
use h_svm_lru::hdfs::{BlockId, BlockKind};
use h_svm_lru::sim::SimTime;
use h_svm_lru::svm::features::N_FEATURES;
use h_svm_lru::svm::kernel::{KernelKind, KernelParams};
use h_svm_lru::svm::smo::SmoModel;
use h_svm_lru::util::bytes::MB;
use h_svm_lru::workload::BlockRequest;

/// A model whose decision is the constant `bias` — version `v` is
/// published with bias `+v` so readers can check snapshot consistency.
fn constant_model(bias: f32) -> SmoModel {
    SmoModel::new(
        KernelParams::new(KernelKind::Linear),
        Vec::new(),
        Vec::new(),
        Vec::new(),
        bias,
    )
}

#[test]
fn concurrent_readers_see_monotone_consistent_snapshots() {
    const PUBLISHES: u64 = 200;
    const READERS: usize = 4;
    let cell = Arc::new(SnapshotCell::new());
    let publisher_done = Arc::new(AtomicBool::new(false));
    let start = Arc::new(Barrier::new(READERS + 1));

    std::thread::scope(|scope| {
        for _ in 0..READERS {
            let cell = Arc::clone(&cell);
            let done = Arc::clone(&publisher_done);
            let start = Arc::clone(&start);
            scope.spawn(move || {
                let mut reader = SnapshotReader::new(cell);
                let mut last_version = 0u64;
                let features = [0.0f32; N_FEATURES];
                start.wait();
                loop {
                    let finished = done.load(Ordering::Acquire);
                    let snap = reader.current();
                    let v = snap.version();
                    assert!(
                        v >= last_version,
                        "version went backwards: {last_version} -> {v}"
                    );
                    last_version = v;
                    // Consistency: version v was published with bias +v,
                    // so a torn version/model pair would show up here.
                    match snap.decision(&features) {
                        None => assert_eq!(v, 0, "trained snapshot lost its model"),
                        Some(score) => {
                            assert_eq!(score, v as f32, "snapshot {v} carries wrong model")
                        }
                    }
                    // One more pass after the publisher finished, so every
                    // reader provably converges to the final version.
                    if finished {
                        break;
                    }
                }
                let snap = reader.current();
                assert_eq!(snap.version(), PUBLISHES, "reader must converge");
            });
        }
        start.wait();
        for v in 1..=PUBLISHES {
            let published = cell.publish(constant_model(v as f32));
            assert_eq!(published, v, "publisher owns the version sequence");
        }
        publisher_done.store(true, Ordering::Release);
    });
    assert_eq!(cell.version(), PUBLISHES);
}

/// A trace where no block is ever re-requested: every label is negative,
/// the classifier is untrainable, and the online trainer must never
/// publish.
fn single_class_trace(n: usize) -> Vec<BlockRequest> {
    (0..n)
        .map(|i| BlockRequest {
            time: SimTime(i as u64 * 1_000_000),
            block: BlockId(i as u64),
            size: 64 * MB,
            kind: BlockKind::Intermediate,
            affinity: CacheAffinity::Low,
            reused_later: false,
            recompute_cost: 0.0,
        })
        .collect()
}

#[test]
fn online_without_publishes_matches_frozen_and_classify_once() {
    let trace = single_class_trace(300);
    let capacity = 8 * 64 * MB;
    for shards in [1usize, 8] {
        let online = run_online(
            "h-svm-lru",
            shards,
            capacity,
            &trace,
            Mode::Online,
            KernelKind::Rbf,
            TrainerConfig::default(),
            BatcherConfig::default(),
            RecencyConfig::default(),
        )
        .unwrap();
        assert_eq!(online.trainer.publishes, 0, "single class must not train");
        assert_eq!(online.trainer.trainings, 0);
        assert_eq!(online.snapshot_refreshes, 0);
        assert_eq!(
            online.trainer.samples,
            trace.len() as u64,
            "trainer still consumed the stream"
        );

        let frozen = run_online(
            "h-svm-lru",
            shards,
            capacity,
            &trace,
            Mode::Frozen,
            KernelKind::Rbf,
            TrainerConfig::default(),
            BatcherConfig::default(),
            RecencyConfig::default(),
        )
        .unwrap();
        assert_eq!(frozen.trainer.final_version, 0, "nothing to pretrain on");

        let classes = classify_trace(&trace, KernelKind::Rbf, 64).unwrap();
        assert!(classes.iter().all(|c| c.is_none()));
        let baseline = replay(
            "h-svm-lru",
            shards,
            capacity,
            &trace,
            &ReplayOptions::new().classes(&classes),
        )
        .unwrap()
        .report;

        assert_eq!(online.stats, baseline.stats, "{shards}-shard online parity");
        assert_eq!(online.per_shard, baseline.per_shard);
        assert_eq!(frozen.stats, baseline.stats, "{shards}-shard frozen parity");
        assert_eq!(frozen.per_shard, baseline.per_shard);
    }
}

/// Mode labels and trainer-config defaults (the public CLI surface).
#[test]
fn trainer_mode_labels() {
    assert_eq!(Mode::Frozen.label(), "frozen");
    assert_eq!(Mode::Online.label(), "online");
    let cfg = TrainerConfig::default();
    assert!(cfg.min_samples >= 2);
    assert!(cfg.retrain_interval >= 1);
}

#[test]
fn insert_path_counts_rejections_as_misses_and_merges_exactly() {
    // Ghost admission refuses every first sighting: drive the coordinator's
    // miss path (`ShardedCache::insert`) concurrently and check the
    // accounting end to end.
    let n = 4usize;
    let cache = CacheBuilder::new()
        .policy("lru")
        .admission("ghost")
        .shards(n)
        .capacity(64)
        .build()
        .unwrap();
    let blocks: Vec<BlockId> = (0..120u64).map(BlockId).collect();
    let ctx_of = |t: u64| AccessContext::simple(SimTime(t), 1);

    // Two rounds: first insert of each block is probation-rejected, the
    // re-insert is admitted. Each worker only touches its own shard.
    std::thread::scope(|scope| {
        for w in 0..n {
            let cache = &cache;
            let blocks = &blocks;
            scope.spawn(move || {
                for round in 0..2u64 {
                    for (i, &b) in blocks.iter().enumerate() {
                        if shard_of(b, n) == w && !cache.contains(b) {
                            cache.insert(b, &ctx_of(round * 1000 + i as u64));
                        }
                    }
                }
            });
        }
    });

    let merged = cache.stats();
    let by_hand = cache
        .shard_stats()
        .iter()
        .fold(ShardStats::default(), |mut acc, s| {
            acc.merge(s);
            acc
        });
    assert_eq!(merged, by_hand, "merged stats must equal the per-shard fold");

    // insert() counts every call as a missed request — including the
    // admission-rejected ones (the cache/sharded.rs insert contract).
    assert_eq!(merged.requests, 2 * blocks.len() as u64);
    assert_eq!(merged.misses, merged.requests, "insert path never hits");
    assert_eq!(merged.hits, 0);
    assert_eq!(merged.rejected, blocks.len() as u64, "every first sighting refused");
    assert_eq!(merged.admitted, blocks.len() as u64, "every re-insert admitted");
    assert_eq!(merged.insertions, merged.admitted);
    // Conservation across shards: admitted - evicted = still cached.
    assert_eq!(
        merged.insertions - merged.evictions,
        cache.len() as u64,
        "insertion/eviction conservation"
    );
    assert!(cache.used() <= cache.capacity());
}
