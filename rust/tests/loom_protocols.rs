//! Exhaustive loom models of every lock-free protocol in the crate.
//!
//! Compiled (and meaningful) only under `RUSTFLAGS="--cfg loom"`, which
//! swaps the `crate::util::sync` facade from `std::sync` onto loom's
//! instrumented primitives — see that module and docs/CONCURRENCY.md.
//! Each `loom::model` below enumerates every interleaving (bounded by
//! `LOOM_MAX_PREEMPTIONS` in CI) of a small instance of one protocol and
//! asserts its invariant in all of them:
//!
//! 1. seqlock ([`AtomicShardStats`]): a snapshot taken concurrently with
//!    write sections is never torn — counters from different sections
//!    cannot mix.
//! 2. histogram slots ([`LogHistogram`]): a lock-free cross-shard merge
//!    taken mid-write observes only whole records, and post-join totals
//!    are exact.
//! 3. [`SnapshotCell`]: the version counter never runs ahead of the slot,
//!    readers observe versions monotonically, and version ↔ model state
//!    stay consistent.
//! 4. [`BatcherProbe`]: cold-query counters shared by concurrent shard
//!    batchers conserve `cold == flushed + dropped` at quiescence with
//!    `deferred <= cold`.
//! 5. [`ReadView`] (the lock-free membership table behind the batched
//!    recency hit path): probes racing the single lock-holding writer's
//!    insert / remove / rebuild never observe a torn table — a block
//!    resident throughout is never reported `Miss`, a block never
//!    inserted is never reported `Hit`, and the seqlock retry makes
//!    every probe linearize against rebuilds.
//!
//! Run with:
//! `RUSTFLAGS="--cfg loom" LOOM_MAX_PREEMPTIONS=3 cargo test --release --test loom_protocols`
#![cfg(loom)]

use std::sync::Arc;

use anyhow::Result;

use h_svm_lru::cache::read_path::{Probe, ReadView};
use h_svm_lru::cache::shard_stats::AtomicShardStats;
use h_svm_lru::coordinator::batcher::{BatcherConfig, BatcherProbe, ShardBatcher};
use h_svm_lru::coordinator::online::SnapshotCell;
use h_svm_lru::hdfs::BlockId;
use h_svm_lru::obs::LogHistogram;
use h_svm_lru::runtime::SvmBackend;
use h_svm_lru::sim::{SimDuration, SimTime};
use h_svm_lru::svm::features::{FeatureVec, N_FEATURES};
use h_svm_lru::svm::kernel::{KernelKind, KernelParams};
use h_svm_lru::svm::smo::SmoModel;

/// A model whose decision is a constant: sign(bias). Publishing these
/// makes every version's predictions distinguishable, so a reader can be
/// checked for version ↔ model consistency.
fn constant_model(bias: f32) -> SmoModel {
    SmoModel::new(
        KernelParams::new(KernelKind::Linear),
        Vec::new(),
        Vec::new(),
        Vec::new(),
        bias,
    )
}

fn fv() -> FeatureVec {
    [0.0f32; N_FEATURES]
}

/// Protocol 1 — the seqlock stats block. One writer (the shard lock
/// holder) runs two write sections while a reader snapshots concurrently.
/// `used` is set to `requests` inside every section, so *any* mix of
/// fields from different sections breaks one of the equalities below.
#[test]
fn seqlock_snapshot_is_never_torn() {
    loom::model(|| {
        let stats = Arc::new(AtomicShardStats::new());
        let writer = {
            let stats = Arc::clone(&stats);
            loom::thread::spawn(move || {
                {
                    let mut w = stats.write();
                    w.record_request(true, false, 0);
                    w.set_occupancy(1, 1);
                }
                {
                    let mut w = stats.write();
                    w.record_request(false, true, 1);
                    w.set_occupancy(2, 2);
                }
            })
        };

        // Concurrent snapshot: must come from exactly one even-seq state.
        let snap = stats.snapshot();
        assert_eq!(
            snap.stats.hits + snap.stats.misses,
            snap.stats.requests,
            "counters from different write sections mixed"
        );
        assert!(snap.stats.requests <= 2);
        assert_eq!(
            snap.used, snap.stats.requests,
            "occupancy mirror from a different section than the counters"
        );
        assert_eq!(snap.used, snap.blocks);

        writer.join().unwrap();
        let fin = stats.snapshot();
        assert_eq!(fin.stats.requests, 2);
        assert_eq!(fin.stats.hits, 1);
        assert_eq!(fin.stats.misses, 1);
        assert_eq!(fin.stats.insertions, 1);
        assert_eq!(fin.stats.evictions, 1);
        assert_eq!(fin.used, 2);
        assert_eq!(fin.blocks, 2);
    });
}

/// Protocol 2 — per-shard histogram slots. Two single-writer histograms
/// record concurrently while the main thread takes a lock-free merged
/// snapshot. Each per-shard snapshot must be one of that shard's committed
/// prefixes (never a torn half-record), and the post-join merge is exact.
#[test]
fn histogram_merge_observes_only_whole_records() {
    loom::model(|| {
        let a = Arc::new(LogHistogram::new());
        let b = Arc::new(LogHistogram::new());
        let ta = {
            let a = Arc::clone(&a);
            loom::thread::spawn(move || {
                a.record(1);
                a.record(2);
            })
        };
        let tb = {
            let b = Arc::clone(&b);
            loom::thread::spawn(move || {
                b.record(3);
            })
        };

        // Concurrent merge: shard a has committed prefixes {}, {1}, {1,2};
        // shard b has {}, {3}. Anything else is a torn read.
        let sa = a.snapshot();
        assert!(
            matches!((sa.count, sa.sum), (0, 0) | (1, 1) | (2, 3)),
            "shard a snapshot ({}, {}) is not a committed prefix",
            sa.count,
            sa.sum
        );
        let sb = b.snapshot();
        assert!(
            matches!((sb.count, sb.sum), (0, 0) | (1, 3)),
            "shard b snapshot ({}, {}) is not a committed prefix",
            sb.count,
            sb.sum
        );
        let mut merged = sa.clone();
        merged.merge(&sb);
        let bucket_total: u64 = merged.buckets.iter().sum();
        assert_eq!(bucket_total, merged.count, "merged bucket counts disagree with count");

        ta.join().unwrap();
        tb.join().unwrap();
        let mut fin = a.snapshot();
        fin.merge(&b.snapshot());
        assert_eq!(fin.count, 3, "a committed record went missing");
        assert_eq!(fin.sum, 6, "a committed value went missing");
        let fin_total: u64 = fin.buckets.iter().sum();
        assert_eq!(fin_total, 3);
    });
}

/// Protocol 3 — the snapshot publication cell. A publisher pushes two
/// models while the main thread reads; the version counter may lag the
/// slot but can never run ahead of it, reader versions are monotone, and
/// each version predicts exactly its model's class.
#[test]
fn snapshot_cell_version_never_runs_ahead_of_the_slot() {
    loom::model(|| {
        let cell = Arc::new(SnapshotCell::new());
        let publisher = {
            let cell = Arc::clone(&cell);
            loom::thread::spawn(move || {
                assert_eq!(cell.publish(constant_model(1.0)), 1);
                assert_eq!(cell.publish(constant_model(-1.0)), 2);
            })
        };

        // The issue's litmus: observe the version, then take the slot —
        // the slot must hold a snapshot at least that fresh.
        let v = cell.version();
        let snap = cell.load();
        assert!(
            snap.version() >= v,
            "version {} ran ahead of slot version {}",
            v,
            snap.version()
        );
        // version ↔ model consistency on whatever state we caught.
        assert_eq!(snap.is_trained(), snap.version() > 0);
        match snap.version() {
            0 => assert_eq!(snap.predict(&fv()), None),
            1 => assert_eq!(snap.predict(&fv()), Some(true)),
            2 => assert_eq!(snap.predict(&fv()), Some(false)),
            v => panic!("impossible version {v}"),
        }
        // Version monotonicity, raw and through a cached reader.
        let v2 = cell.version();
        assert!(v2 >= v, "cell version went backwards: {v} -> {v2}");
        let mut reader = cell.reader();
        let r1 = reader.current().version();
        let r2 = reader.current().version();
        assert!(r2 >= r1, "reader version went backwards: {r1} -> {r2}");

        publisher.join().unwrap();
        let fin = cell.load();
        assert_eq!(cell.version(), 2);
        assert_eq!(fin.version(), 2);
        assert_eq!(fin.predict(&fv()), Some(false), "last published model wins");
        assert_eq!(reader.predict(&fv()), Some(false), "reader refreshes to the tip");
    });
}

/// Stub backend for the probe model: classifies everything `true`,
/// never fails (drop accounting is covered by non-loom unit tests).
struct FakeBackend;

impl SvmBackend for FakeBackend {
    fn name(&self) -> &'static str {
        "fake"
    }
    fn train(&mut self, _ds: &h_svm_lru::svm::Dataset) -> Result<()> {
        Ok(())
    }
    fn decision_batch(&mut self, q: &[FeatureVec]) -> Result<Vec<f32>> {
        Ok(q.iter().map(|_| 1.0).collect())
    }
    fn is_trained(&self) -> bool {
        true
    }
}

/// Protocol 4 — shared cold-path counters. Two shard batchers (the
/// [`BatcherPool`] topology: private queues, one shared probe) each defer
/// one query and fill-flush a second, concurrently. A concurrent reader
/// may only rely on per-counter monotonicity (the stores are relaxed, so
/// cross-counter inequalities need not hold mid-flight — C11 permits the
/// inversion and loom finds it); at quiescence the books must balance:
/// `deferred <= cold == flushed + dropped`.
#[test]
fn probe_counters_conserve_cold_queries() {
    loom::model(|| {
        let probe = BatcherProbe::new();
        let cfg = BatcherConfig {
            queue_depth: 2,
            deadline: SimDuration::from_secs_f64(3600.0), // never lapses in-model
            ..BatcherConfig::default()
        };
        let workers: Vec<_> = (0..2u64)
            .map(|t| {
                let probe = probe.clone();
                loom::thread::spawn(move || {
                    let mut be = FakeBackend;
                    let mut batcher = ShardBatcher::with_probe(cfg, probe);
                    let base = t * 10;
                    // First cold query defers below the fill bound…
                    let r = batcher
                        .predict(&mut be, BlockId(base), 0, fv(), SimTime(0))
                        .unwrap();
                    assert_eq!(r, None, "depth-2 queue must defer the first query");
                    // …the second fills the queue and flushes both.
                    let r = batcher
                        .predict(&mut be, BlockId(base + 1), 0, fv(), SimTime(1))
                        .unwrap();
                    assert_eq!(r, Some(true));
                    batcher.flush(&mut be).unwrap(); // empty-queue no-op
                })
            })
            .collect();

        // Concurrent reads: each individual counter is monotone
        // (per-atomic coherence) — the only concurrent guarantee relaxed
        // counters give.
        let c1 = probe.cold_queries();
        let c2 = probe.cold_queries();
        assert!(c2 >= c1, "cold counter went backwards: {c1} -> {c2}");
        assert!(c2 <= 4);

        for w in workers {
            w.join().unwrap();
        }
        // Quiescence (joins give happens-before): exact conservation.
        assert_eq!(probe.cold_queries(), 4);
        assert_eq!(probe.deferred(), 2);
        assert!(probe.deferred() <= probe.cold_queries());
        assert_eq!(probe.dropped(), 0);
        assert_eq!(
            probe.flushed_queries() + probe.dropped(),
            probe.cold_queries(),
            "cold-query conservation broken"
        );
        assert_eq!(probe.flushes(), 2);
        assert_eq!(probe.flushes_by_fill(), 2);
    });
}

/// Protocol 5 — the read-view seqlock. One writer (standing in for the
/// shard-lock holder: mutators are single-writer by construction) inserts
/// a block, rebuilds the table and removes the block again, while the
/// main thread probes concurrently. In every interleaving:
///
/// * the pinned block — resident before the writer starts and kept by the
///   rebuild — must never probe `Miss` (a racy publish may conservatively
///   demote to the locked path, but the view is never *wrong* about it);
/// * a block that is never inserted must never probe `Hit`;
/// * the churned block may probe either way mid-flight (both linearize),
///   but the final state after the join is exact.
#[test]
fn read_view_probes_survive_insert_remove_and_rebuild() {
    const PINNED: BlockId = BlockId(1_000);
    const CHURNED: BlockId = BlockId(2);
    const ABSENT: BlockId = BlockId(3);
    loom::model(|| {
        let view = Arc::new(ReadView::with_slots(16));
        view.insert(PINNED); // happens-before the writer via spawn
        let writer = {
            let view = Arc::clone(&view);
            loom::thread::spawn(move || {
                view.insert(CHURNED);
                // The only multi-slot write: seqlock-bracketed compaction.
                view.rebuild([PINNED, CHURNED].into_iter());
                view.remove(CHURNED);
            })
        };

        // Concurrent probes: retried across rebuilds by the seqlock.
        assert_ne!(view.probe(PINNED), Probe::Miss, "pinned block reported missing");
        assert_ne!(view.probe(ABSENT), Probe::Hit, "phantom block reported resident");
        let _ = view.probe(CHURNED); // any verdict linearizes; must not hang

        writer.join().unwrap();
        assert!(!view.is_saturated(), "tiny population must never saturate");
        assert_eq!(view.probe(PINNED), Probe::Hit);
        assert_eq!(view.probe(CHURNED), Probe::Miss);
        assert_eq!(view.probe(ABSENT), Probe::Miss);
    });
}
