//! Property tests for the O(1) eviction hot path.
//!
//! Every policy that moved off `BTreeMap`/`VecDeque` ordering onto the
//! slab-backed intrusive `OrderList` (plus SlruK's ordered victim index)
//! must be **access-for-access identical** to the implementation it
//! replaced. The original order logic is kept here, verbatim, as reference
//! models (`Ref*`), and both sides are driven through `BlockCache` with
//! the same randomized traces — every `AccessOutcome` (hit/miss, victim
//! set, admission decision) must match, request by request.
//!
//! Also: `OrderList` itself is differential-tested against a `VecDeque`
//! model, and its free-list reuse + handle stability guarantees are
//! asserted directly.

use std::collections::{BTreeMap, HashMap, VecDeque};

use h_svm_lru::cache::admission::{AdmissionPolicy, GhostProbation};
use h_svm_lru::cache::order_list::{OrderHandle, OrderList};
use h_svm_lru::cache::registry::make_policy;
use h_svm_lru::cache::{AccessContext, BlockCache, CacheBuilder, CachePolicy};
use h_svm_lru::hdfs::BlockId;
use h_svm_lru::sim::SimTime;
use h_svm_lru::util::fasthash::IdHashMap;
use h_svm_lru::util::rng::Pcg64;

// ------------------------------------------------------------------------
// Reference models: the pre-OrderList order logic, kept bit for bit.
// ------------------------------------------------------------------------

/// The original BTreeMap-ordered LRU.
#[derive(Default)]
struct RefLru {
    order: BTreeMap<i64, BlockId>,
    index: IdHashMap<BlockId, i64>,
    next: i64,
}

impl RefLru {
    fn touch(&mut self, block: BlockId) {
        if let Some(old) = self.index.remove(&block) {
            self.order.remove(&old);
        }
        let key = self.next;
        self.next += 1;
        self.order.insert(key, block);
        self.index.insert(block, key);
    }
}

impl CachePolicy for RefLru {
    fn name(&self) -> &'static str {
        "ref-lru"
    }
    fn on_hit(&mut self, block: BlockId, _ctx: &AccessContext) {
        self.touch(block);
    }
    fn on_insert(&mut self, block: BlockId, _ctx: &AccessContext) {
        self.touch(block);
    }
    fn choose_victim(&mut self, _now: SimTime) -> Option<BlockId> {
        self.order.values().next().copied()
    }
    fn on_evict(&mut self, block: BlockId) {
        if let Some(key) = self.index.remove(&block) {
            self.order.remove(&key);
        }
    }
    fn len(&self) -> usize {
        self.index.len()
    }
}

/// The original BTreeMap-ordered FIFO.
#[derive(Default)]
struct RefFifo {
    order: BTreeMap<i64, BlockId>,
    index: HashMap<BlockId, i64>,
    next: i64,
}

impl CachePolicy for RefFifo {
    fn name(&self) -> &'static str {
        "ref-fifo"
    }
    fn on_hit(&mut self, _block: BlockId, _ctx: &AccessContext) {}
    fn on_insert(&mut self, block: BlockId, _ctx: &AccessContext) {
        let key = self.next;
        self.next += 1;
        self.order.insert(key, block);
        self.index.insert(block, key);
    }
    fn choose_victim(&mut self, _now: SimTime) -> Option<BlockId> {
        self.order.values().next().copied()
    }
    fn on_evict(&mut self, block: BlockId) {
        if let Some(key) = self.index.remove(&block) {
            self.order.remove(&key);
        }
    }
    fn len(&self) -> usize {
        self.index.len()
    }
}

/// The original BTreeMap-ordered LFU ((frequency, last-access seq) keys
/// re-inserted on every access; victim = first entry).
#[derive(Default)]
struct RefLfu {
    order: BTreeMap<(u64, i64), BlockId>,
    index: HashMap<BlockId, (u64, i64)>,
    seq: i64,
}

impl RefLfu {
    fn bump(&mut self, block: BlockId, add: u64) {
        let (freq, old_seq) = self.index.remove(&block).unwrap_or((0, 0));
        if freq > 0 || old_seq != 0 {
            self.order.remove(&(freq, old_seq));
        }
        let seq = self.seq;
        self.seq += 1;
        let entry = (freq + add, seq);
        self.order.insert(entry, block);
        self.index.insert(block, entry);
    }
}

impl CachePolicy for RefLfu {
    fn name(&self) -> &'static str {
        "ref-lfu"
    }
    fn on_hit(&mut self, block: BlockId, _ctx: &AccessContext) {
        self.bump(block, 1);
    }
    fn on_insert(&mut self, block: BlockId, _ctx: &AccessContext) {
        self.bump(block, 1);
    }
    fn choose_victim(&mut self, _now: SimTime) -> Option<BlockId> {
        self.order.values().next().copied()
    }
    fn on_evict(&mut self, block: BlockId) {
        if let Some(entry) = self.index.remove(&block) {
            self.order.remove(&entry);
        }
    }
    fn len(&self) -> usize {
        self.index.len()
    }
}

/// The original two-BTreeMap H-SVM-LRU.
#[derive(Clone, Copy, PartialEq, Eq)]
enum RefRegion {
    Unused,
    Reused,
}

#[derive(Default)]
struct RefHSvmLru {
    unused: BTreeMap<i64, BlockId>,
    reused: BTreeMap<i64, BlockId>,
    index: IdHashMap<BlockId, (RefRegion, i64)>,
    next_hi: i64,
    next_lo: i64,
}

impl RefHSvmLru {
    fn detach(&mut self, block: BlockId) {
        if let Some((region, key)) = self.index.remove(&block) {
            match region {
                RefRegion::Unused => self.unused.remove(&key),
                RefRegion::Reused => self.reused.remove(&key),
            };
        }
    }

    fn push_back(&mut self, region: RefRegion, block: BlockId) {
        let key = self.next_hi;
        self.next_hi += 1;
        match region {
            RefRegion::Unused => self.unused.insert(key, block),
            RefRegion::Reused => self.reused.insert(key, block),
        };
        self.index.insert(block, (region, key));
    }

    fn push_front_unused(&mut self, block: BlockId) {
        self.next_lo -= 1;
        let key = self.next_lo;
        self.unused.insert(key, block);
        self.index.insert(block, (RefRegion::Unused, key));
    }

    fn classify(ctx: &AccessContext) -> bool {
        ctx.predicted_reuse.unwrap_or(true)
    }
}

impl CachePolicy for RefHSvmLru {
    fn name(&self) -> &'static str {
        "ref-h-svm-lru"
    }
    fn on_hit(&mut self, block: BlockId, ctx: &AccessContext) {
        self.detach(block);
        if Self::classify(ctx) {
            self.push_back(RefRegion::Reused, block);
        } else {
            self.push_front_unused(block);
        }
    }
    fn on_insert(&mut self, block: BlockId, ctx: &AccessContext) {
        if Self::classify(ctx) {
            self.push_back(RefRegion::Reused, block);
        } else {
            self.push_back(RefRegion::Unused, block);
        }
    }
    fn choose_victim(&mut self, _now: SimTime) -> Option<BlockId> {
        self.unused
            .values()
            .next()
            .or_else(|| self.reused.values().next())
            .copied()
    }
    fn on_evict(&mut self, block: BlockId) {
        self.detach(block);
    }
    fn len(&self) -> usize {
        self.index.len()
    }
}

/// The original VecDeque-based Modified ARC (O(n) ghost removals and all).
#[derive(Clone, Copy, PartialEq, Eq)]
enum RefList {
    Recent,
    Frequent,
}

struct RefArc {
    t1: VecDeque<BlockId>,
    t2: VecDeque<BlockId>,
    where_is: HashMap<BlockId, RefList>,
    b1: VecDeque<BlockId>,
    b2: VecDeque<BlockId>,
    ghost_cap: usize,
    p: f64,
}

impl RefArc {
    fn new(ghost_cap: usize) -> Self {
        RefArc {
            t1: VecDeque::new(),
            t2: VecDeque::new(),
            where_is: HashMap::new(),
            b1: VecDeque::new(),
            b2: VecDeque::new(),
            ghost_cap: ghost_cap.max(1),
            p: 0.0,
        }
    }

    fn ghost_remove(list: &mut VecDeque<BlockId>, block: BlockId) -> bool {
        if let Some(pos) = list.iter().position(|&b| b == block) {
            list.remove(pos);
            true
        } else {
            false
        }
    }

    fn ghost_push(list: &mut VecDeque<BlockId>, cap: usize, block: BlockId) {
        list.push_back(block);
        while list.len() > cap {
            list.pop_front();
        }
    }
}

impl CachePolicy for RefArc {
    fn name(&self) -> &'static str {
        "ref-modified-arc"
    }
    fn on_hit(&mut self, block: BlockId, _ctx: &AccessContext) {
        match self.where_is.get(&block) {
            Some(RefList::Recent) => {
                Self::ghost_remove(&mut self.t1, block);
            }
            Some(RefList::Frequent) => {
                Self::ghost_remove(&mut self.t2, block);
            }
            None => panic!("hit on untracked block"),
        }
        self.t2.push_back(block);
        self.where_is.insert(block, RefList::Frequent);
    }
    fn on_insert(&mut self, block: BlockId, _ctx: &AccessContext) {
        let total = (self.t1.len() + self.t2.len()).max(1) as f64;
        if Self::ghost_remove(&mut self.b1, block) {
            let delta = (self.b2.len().max(1) as f64 / self.b1.len().max(1) as f64).max(1.0);
            self.p = (self.p + delta).min(total);
            self.t2.push_back(block);
            self.where_is.insert(block, RefList::Frequent);
        } else if Self::ghost_remove(&mut self.b2, block) {
            let delta = (self.b1.len().max(1) as f64 / self.b2.len().max(1) as f64).max(1.0);
            self.p = (self.p - delta).max(0.0);
            self.t2.push_back(block);
            self.where_is.insert(block, RefList::Frequent);
        } else {
            self.t1.push_back(block);
            self.where_is.insert(block, RefList::Recent);
        }
    }
    fn choose_victim(&mut self, _now: SimTime) -> Option<BlockId> {
        if !self.t1.is_empty() && (self.t1.len() as f64 > self.p || self.t2.is_empty()) {
            self.t1.front().copied()
        } else {
            self.t2.front().copied().or_else(|| self.t1.front().copied())
        }
    }
    fn on_evict(&mut self, block: BlockId) {
        match self.where_is.remove(&block) {
            Some(RefList::Recent) => {
                Self::ghost_remove(&mut self.t1, block);
                Self::ghost_push(&mut self.b1, self.ghost_cap, block);
            }
            Some(RefList::Frequent) => {
                Self::ghost_remove(&mut self.t2, block);
                Self::ghost_push(&mut self.b2, self.ghost_cap, block);
            }
            None => {}
        }
    }
    fn len(&self) -> usize {
        self.where_is.len()
    }
}

/// The original full-scan Selective LRU-K (weight recomputed per victim
/// scan against `now`).
struct RefSlruK {
    k: usize,
    entries: HashMap<BlockId, VecDeque<SimTime>>,
    seen: HashMap<BlockId, u64>,
    selective_threshold: u64,
    size_weight: f64,
}

impl RefSlruK {
    fn new(k: usize) -> Self {
        RefSlruK {
            k: k.max(1),
            entries: HashMap::new(),
            seen: HashMap::new(),
            selective_threshold: 2,
            size_weight: 1.0,
        }
    }

    fn weight(&self, times: &VecDeque<SimTime>, now: SimTime) -> (bool, f64) {
        let complete = times.len() >= self.k;
        let reference = if complete {
            times[times.len() - self.k]
        } else {
            *times.back().expect("empty access history")
        };
        let age = reference.duration_until(now).as_secs_f64();
        let recency_score = 1.0 / (1.0 + age);
        (complete, recency_score * self.size_weight)
    }
}

impl CachePolicy for RefSlruK {
    fn name(&self) -> &'static str {
        "ref-slru-k"
    }
    fn on_hit(&mut self, block: BlockId, ctx: &AccessContext) {
        *self.seen.entry(block).or_insert(0) += 1;
        let times = self.entries.get_mut(&block).expect("hit on untracked block");
        times.push_back(ctx.time);
        while times.len() > self.k {
            times.pop_front();
        }
    }
    fn on_insert(&mut self, block: BlockId, ctx: &AccessContext) {
        *self.seen.entry(block).or_insert(0) += 1;
        let mut times = VecDeque::with_capacity(self.k);
        times.push_back(ctx.time);
        self.entries.insert(block, times);
    }
    fn admits(&self, block: BlockId, _ctx: &AccessContext) -> bool {
        self.seen.contains_key(&block)
            || (self.entries.len() as u64) < self.selective_threshold
    }
    fn choose_victim(&mut self, now: SimTime) -> Option<BlockId> {
        self.entries
            .iter()
            .min_by(|(ba, ta), (bb, tb)| {
                let wa = self.weight(ta, now);
                let wb = self.weight(tb, now);
                wa.partial_cmp(&wb).unwrap().then(ba.cmp(bb))
            })
            .map(|(b, _)| *b)
    }
    fn on_evict(&mut self, block: BlockId) {
        self.entries.remove(&block);
    }
    fn len(&self) -> usize {
        self.entries.len()
    }
}

/// The original stamped-lazy-deletion ghost LRU behind the `ghost`
/// admission policy.
#[derive(Default)]
struct RefGhostLru {
    stamps: IdHashMap<BlockId, u64>,
    queue: VecDeque<(BlockId, u64)>,
    seq: u64,
    capacity: usize,
}

impl RefGhostLru {
    fn new(capacity: usize) -> Self {
        RefGhostLru { capacity: capacity.max(1), ..Default::default() }
    }

    fn record(&mut self, block: BlockId) {
        self.seq += 1;
        self.stamps.insert(block, self.seq);
        self.queue.push_back((block, self.seq));
        while self.stamps.len() > self.capacity {
            let (b, s) = self.queue.pop_front().expect("members imply queue entries");
            if self.stamps.get(&b) == Some(&s) {
                self.stamps.remove(&b);
            }
        }
        while let Some(&(b, s)) = self.queue.front() {
            if self.stamps.get(&b) == Some(&s) {
                break;
            }
            self.queue.pop_front();
        }
        if self.queue.len() > 2 * self.capacity {
            let stamps = &self.stamps;
            self.queue.retain(|(b, s)| stamps.get(b) == Some(s));
        }
    }

    fn remove(&mut self, block: BlockId) -> bool {
        self.stamps.remove(&block).is_some()
    }
}

struct RefGhostProbation {
    ghost: RefGhostLru,
}

impl AdmissionPolicy for RefGhostProbation {
    fn name(&self) -> &'static str {
        "ref-ghost"
    }
    fn on_access(&mut self, _block: BlockId, _ctx: &AccessContext) {}
    fn admit(
        &mut self,
        candidate: BlockId,
        _ctx: &AccessContext,
        _victim: &mut dyn FnMut() -> Option<BlockId>,
    ) -> bool {
        if self.ghost.remove(candidate) {
            true
        } else {
            self.ghost.record(candidate);
            false
        }
    }
    fn on_evict(&mut self, block: BlockId) {
        self.ghost.record(block);
    }
}

// ------------------------------------------------------------------------
// Differential drivers
// ------------------------------------------------------------------------

/// Replay a randomized (monotone-time) trace through two caches and demand
/// identical outcomes — hit/miss, inserted flag and the exact victim list —
/// on every request, plus identical final contents.
fn assert_trace_parity(mut real: BlockCache, mut reference: BlockCache, seed: u64) {
    let mut rng = Pcg64::new(seed, 0xD1FF);
    let keyspace = 48u64;
    for t in 0..4_000u64 {
        let block = BlockId(rng.gen_range(keyspace));
        let size = 1 + rng.gen_range(3);
        let mut ctx = AccessContext::simple(SimTime(t), size);
        if rng.gen_bool(0.8) {
            ctx = ctx.with_prediction(rng.gen_bool(0.5));
        }
        let a = real.access_or_insert(block, &ctx);
        let b = reference.access_or_insert(block, &ctx);
        assert_eq!(a, b, "outcome divergence at t={t} block={block:?}");
        // Occasional external uncache exercises on_evict outside the
        // victim loop.
        if rng.gen_bool(0.03) {
            let victim = BlockId(rng.gen_range(keyspace));
            assert_eq!(real.remove(victim), reference.remove(victim), "remove divergence at t={t}");
        }
    }
    assert_eq!(real.cached_blocks(), reference.cached_blocks());
    assert_eq!(real.used(), reference.used());
    assert_eq!(real.admission_stats(), reference.admission_stats());
}

fn registry_policy(name: &str) -> Box<dyn CachePolicy> {
    make_policy(name).expect("registry policy")
}

#[test]
fn lru_matches_btreemap_reference() {
    for seed in 0..6u64 {
        assert_trace_parity(
            BlockCache::new(registry_policy("lru"), 24),
            BlockCache::new(Box::<RefLru>::default(), 24),
            seed,
        );
    }
}

#[test]
fn fifo_matches_btreemap_reference() {
    for seed in 0..6u64 {
        assert_trace_parity(
            BlockCache::new(registry_policy("fifo"), 24),
            BlockCache::new(Box::<RefFifo>::default(), 24),
            seed,
        );
    }
}

#[test]
fn lfu_matches_btreemap_reference() {
    // The O(1) frequency-bucket LFU must be access-for-access identical
    // to the per-access BTreeMap re-key implementation it replaced
    // (frequency order, recency tie-break, eviction resets — all of it).
    for seed in 0..6u64 {
        assert_trace_parity(
            BlockCache::new(registry_policy("lfu"), 24),
            BlockCache::new(Box::<RefLfu>::default(), 24),
            seed,
        );
    }
}

#[test]
fn hsvmlru_matches_two_btreemap_reference() {
    for seed in 0..6u64 {
        assert_trace_parity(
            BlockCache::new(registry_policy("h-svm-lru"), 24),
            BlockCache::new(Box::<RefHSvmLru>::default(), 24),
            seed,
        );
    }
}

#[test]
fn modified_arc_matches_vecdeque_reference() {
    for seed in 0..6u64 {
        // Ghost cap 64 = the registry default for modified-arc.
        assert_trace_parity(
            BlockCache::new(registry_policy("modified-arc"), 24),
            BlockCache::new(Box::new(RefArc::new(64)), 24),
            seed,
        );
    }
}

#[test]
fn slru_k_matches_full_scan_reference() {
    for seed in 0..6u64 {
        // K = 2 = the registry default for slru-k.
        assert_trace_parity(
            BlockCache::new(registry_policy("slru-k"), 24),
            BlockCache::new(Box::new(RefSlruK::new(2)), 24),
            seed,
        );
    }
}

#[test]
fn ghost_admission_matches_stamped_reference() {
    for seed in 0..6u64 {
        let capacity = 32;
        assert_trace_parity(
            CacheBuilder::new()
                .policy("lru")
                .admission_with(move || Box::new(GhostProbation::new(capacity)))
                .capacity(24)
                .build_block_cache()
                .expect("gated lru"),
            CacheBuilder::new()
                .policy_with(|| Box::<RefLru>::default())
                .admission_with(move || {
                    Box::new(RefGhostProbation { ghost: RefGhostLru::new(capacity) })
                })
                .capacity(24)
                .build_block_cache()
                .expect("gated reference lru"),
            seed,
        );
    }
}

// ------------------------------------------------------------------------
// OrderList itself
// ------------------------------------------------------------------------

#[test]
fn order_list_matches_vecdeque_model() {
    let mut rng = Pcg64::new(0x0B5E55ED, 7);
    let mut list: OrderList<u64> = OrderList::new();
    let mut model: VecDeque<u64> = VecDeque::new();
    let mut handles: HashMap<u64, OrderHandle> = HashMap::new();
    let mut next_id = 0u64;
    let mut peak_live = 0usize;
    for step in 0..20_000u64 {
        match rng.gen_range(6) {
            0 | 1 => {
                let id = next_id;
                next_id += 1;
                if rng.gen_bool(0.7) {
                    handles.insert(id, list.push_back(id));
                    model.push_back(id);
                } else {
                    handles.insert(id, list.push_front(id));
                    model.push_front(id);
                }
            }
            2 => {
                if let Some(&id) = model.front() {
                    assert_eq!(list.front(), Some(id));
                    assert_eq!(list.pop_front(), model.pop_front());
                    handles.remove(&id);
                }
            }
            3 => {
                // Unlink a random live element through its stable handle.
                if !model.is_empty() {
                    let pos = rng.gen_range(model.len() as u64) as usize;
                    let id = model.remove(pos).unwrap();
                    let h = handles.remove(&id).unwrap();
                    assert_eq!(list.get(h), id, "handle drifted");
                    assert_eq!(list.unlink(h), id);
                }
            }
            4 => {
                if !model.is_empty() {
                    let pos = rng.gen_range(model.len() as u64) as usize;
                    let id = model.remove(pos).unwrap();
                    model.push_back(id);
                    list.move_to_back(handles[&id]);
                }
            }
            _ => {
                if !model.is_empty() {
                    let pos = rng.gen_range(model.len() as u64) as usize;
                    let id = model.remove(pos).unwrap();
                    model.push_front(id);
                    list.move_to_front(handles[&id]);
                }
            }
        }
        peak_live = peak_live.max(model.len());
        assert_eq!(list.len(), model.len(), "len divergence at step {step}");
        if step % 64 == 0 {
            let got: Vec<u64> = list.iter().collect();
            let want: Vec<u64> = model.iter().copied().collect();
            assert_eq!(got, want, "order divergence at step {step}");
            assert_eq!(list.back(), model.back().copied());
        }
    }
    // Free-list reuse: the slab never outgrows the peak live population.
    assert!(
        list.slots() <= peak_live,
        "slab has {} slots for a peak of {} live nodes",
        list.slots(),
        peak_live
    );
}

#[test]
fn order_list_handles_survive_slot_reuse() {
    // Live handles must keep resolving to their element while freed slots
    // are recycled underneath them.
    let mut list: OrderList<u64> = OrderList::new();
    let keep: Vec<(u64, OrderHandle)> = (0..64u64).map(|i| (i, list.push_back(i))).collect();
    let churn: Vec<OrderHandle> = (1000..1064u64).map(|i| list.push_back(i)).collect();
    for h in churn {
        list.unlink(h);
    }
    let slots_before = list.slots();
    for i in 2000..2064u64 {
        list.push_back(i); // must reuse the 64 freed slots
    }
    assert_eq!(list.slots(), slots_before, "churn slots were not reused");
    for (i, h) in &keep {
        assert_eq!(list.get(*h), *i, "stable handle {i} broke after reuse");
    }
}
