//! Runtime integration: the AOT HLO artifacts (L1 Pallas kernel + L2 JAX
//! model) executed through PJRT from Rust, cross-validated against the
//! pure-Rust SMO reference.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).

use h_svm_lru::runtime::{predict_batch, HloBackend, RustBackend, SvmBackend};
use h_svm_lru::svm::dataset::Dataset;
use h_svm_lru::svm::features::N_FEATURES;
use h_svm_lru::svm::KernelKind;
use h_svm_lru::util::rng::Pcg64;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("ARTIFACTS_DIR").unwrap_or_else(|_| "artifacts".to_string());
    if h_svm_lru::runtime::artifacts::available(std::path::Path::new(&dir), KernelKind::Rbf) {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not found in {dir:?} — run `make artifacts`");
        None
    }
}

fn blobs(n_per: usize, seed: u64, centers: (f64, f64)) -> Dataset {
    let mut rng = Pcg64::new(seed, 0);
    let mut ds = Dataset::new();
    for _ in 0..n_per {
        let mut a = [0.0f32; N_FEATURES];
        let mut b = [0.0f32; N_FEATURES];
        for k in 0..N_FEATURES {
            a[k] = rng.gen_normal(centers.0, 0.08) as f32;
            b[k] = rng.gen_normal(centers.1, 0.08) as f32;
        }
        ds.push(a, true);
        ds.push(b, false);
    }
    ds
}

#[test]
fn hlo_backend_trains_and_classifies() {
    let Some(dir) = artifacts_dir() else { return };
    let mut be = HloBackend::load(&dir, KernelKind::Rbf).expect("load artifacts");
    assert!(!be.is_trained());
    let ds = blobs(80, 3, (0.25, 0.75));
    be.train(&ds).expect("train via PJRT");
    assert!(be.is_trained());
    let classes = predict_batch(&mut be, &ds.x).expect("predict via PJRT");
    let acc = classes
        .iter()
        .zip(&ds.y)
        .filter(|(c, &y)| **c == (y > 0.0))
        .count() as f64
        / ds.len() as f64;
    assert!(acc >= 0.99, "HLO backend accuracy {acc}");
}

#[test]
fn hlo_and_smo_agree_on_classes() {
    let Some(dir) = artifacts_dir() else { return };
    // Overlapping blobs: a harder problem where the decision boundary
    // matters; the two independent implementations must still agree on the
    // vast majority of points.
    let train = blobs(100, 7, (0.35, 0.65));
    let test = blobs(60, 8, (0.35, 0.65));
    let mut hlo = HloBackend::load(&dir, KernelKind::Rbf).unwrap();
    let mut smo = RustBackend::new(KernelKind::Rbf);
    hlo.train(&train).unwrap();
    smo.train(&train).unwrap();
    let ch = predict_batch(&mut hlo, &test.x).unwrap();
    let cs = predict_batch(&mut smo, &test.x).unwrap();
    let agree = ch.iter().zip(&cs).filter(|(a, b)| a == b).count() as f64 / ch.len() as f64;
    assert!(agree >= 0.9, "HLO/SMO class agreement only {agree}");
    // And both should actually be good classifiers here.
    let acc_h = ch.iter().zip(&test.y).filter(|(c, &y)| **c == (y > 0.0)).count() as f64
        / test.len() as f64;
    assert!(acc_h >= 0.85, "HLO acc {acc_h}");
}

#[test]
fn all_three_kernel_artifacts_load_and_run() {
    let Some(dir) = artifacts_dir() else { return };
    let ds = blobs(60, 9, (0.25, 0.75));
    for kind in [KernelKind::Linear, KernelKind::Rbf, KernelKind::Sigmoid] {
        let mut be = HloBackend::load(&dir, kind)
            .unwrap_or_else(|e| panic!("loading {}: {e:#}", kind.name()));
        be.train(&ds).unwrap_or_else(|e| panic!("training {}: {e:#}", kind.name()));
        let scores = be.decision_batch(&ds.x[..10]).unwrap();
        assert_eq!(scores.len(), 10);
        assert!(scores.iter().all(|s| s.is_finite()), "{} scores finite", kind.name());
    }
}

#[test]
fn predict_batches_larger_than_artifact_width() {
    let Some(dir) = artifacts_dir() else { return };
    let mut be = HloBackend::load(&dir, KernelKind::Rbf).unwrap();
    let ds = blobs(80, 4, (0.25, 0.75));
    be.train(&ds).unwrap();
    // 160 queries vs batch width 64: chunking must preserve order.
    let scores = be.decision_batch(&ds.x).unwrap();
    assert_eq!(scores.len(), ds.len());
    let acc = scores
        .iter()
        .zip(&ds.y)
        .filter(|(s, &y)| (**s > 0.0) == (y > 0.0))
        .count() as f64
        / ds.len() as f64;
    assert!(acc >= 0.99, "chunked predict accuracy {acc}");
}

#[test]
fn manifest_matches_crate_constants() {
    let Some(dir) = artifacts_dir() else { return };
    let m = h_svm_lru::runtime::Manifest::load(std::path::Path::new(&dir)).unwrap();
    m.validate().unwrap();
    assert_eq!(m.n_features, N_FEATURES);
    assert!(m.kernels.len() >= 3);
}
