//! Property tests for the sharded cache front: shard-count-1 parity with
//! the bare wrapped policy, multi-shard capacity/accounting invariants,
//! sequential-vs-parallel replay equivalence, and the designated parity
//! pins for the `#[deprecated]` constructor shims (`ShardedCache::{new,
//! with_admission, from_registry, from_registry_with_admission}`,
//! `BlockCache::with_admission`) against [`CacheBuilder`].

use h_svm_lru::cache::registry::{make_policy, POLICY_NAMES};
use h_svm_lru::cache::sharded::{shard_of, ShardStats, ShardedCache};
use h_svm_lru::cache::{AccessContext, BlockCache, CacheBuilder};
use h_svm_lru::hdfs::BlockId;
use h_svm_lru::sim::parallel::{run_fanout, FanoutOptions};
use h_svm_lru::sim::SimTime;
use h_svm_lru::testkit::{forall, CacheOpsGen, Config};

fn ctx(t: u64, reuse: bool) -> AccessContext {
    AccessContext::simple(SimTime(t), 1).with_prediction(reuse)
}

fn sharded(policy: &str, shards: usize, capacity: u64) -> ShardedCache {
    CacheBuilder::new()
        .policy(policy)
        .shards(shards)
        .capacity(capacity)
        .build()
        .unwrap_or_else(|e| panic!("{policy} cache: {e}"))
}

/// Shards = 1 must behave identically to the bare wrapped policy: same hit
/// flags, same eviction sequences, same final contents — for every policy
/// on every op sequence.
#[test]
fn one_shard_equals_bare_policy_for_every_policy() {
    let gen = CacheOpsGen { max_ops: 250, keyspace: 40, max_capacity: 12 };
    for &policy in POLICY_NAMES {
        forall(
            &Config { cases: 20, seed: 0x5AD + policy.len() as u64, ..Default::default() },
            &gen,
            |(ops, cap)| {
                let mut bare = BlockCache::new(make_policy(policy).unwrap(), *cap);
                let front = sharded(policy, 1, *cap);
                for (t, (key, reuse)) in ops.iter().enumerate() {
                    let c = ctx(t as u64, *reuse);
                    let a = bare.access_or_insert(BlockId(*key), &c);
                    let b = front.access_or_insert(BlockId(*key), &c);
                    if a != b {
                        return Err(format!(
                            "{policy}: outcome divergence at op {t}: {a:?} vs {b:?}"
                        ));
                    }
                }
                if bare.cached_blocks() != front.cached_blocks() {
                    return Err(format!("{policy}: final contents diverge"));
                }
                if bare.used() != front.used() {
                    return Err(format!("{policy}: occupancy diverges"));
                }
                Ok(())
            },
        );
    }
}

/// Multi-shard invariants: total occupancy bounded by total capacity, block
/// counts and stats consistent, every block on the shard the hash says.
#[test]
fn multi_shard_capacity_and_accounting_invariants() {
    let gen = CacheOpsGen { max_ops: 300, keyspace: 60, max_capacity: 16 };
    for shards in [2usize, 3, 8] {
        forall(
            &Config { cases: 25, seed: 0x8A2D + shards as u64, ..Default::default() },
            &gen,
            |(ops, cap)| {
                let front = sharded("lru", shards, *cap);
                for (t, (key, reuse)) in ops.iter().enumerate() {
                    front.access_or_insert(BlockId(*key), &ctx(t as u64, *reuse));
                    if front.used() > front.capacity() {
                        return Err(format!(
                            "occupancy {} exceeds capacity {}",
                            front.used(),
                            front.capacity()
                        ));
                    }
                    if front.used() != front.len() as u64 {
                        return Err("byte accounting broken (unit blocks)".into());
                    }
                }
                let stats = front.stats();
                if stats.requests != ops.len() as u64 {
                    return Err(format!(
                        "{} requests counted for {} ops",
                        stats.requests,
                        ops.len()
                    ));
                }
                if stats.hits + stats.misses != stats.requests {
                    return Err("hits + misses != requests".into());
                }
                if stats.insertions < stats.evictions {
                    return Err("evicted more than inserted".into());
                }
                if stats.insertions - stats.evictions != front.len() as u64 {
                    return Err(format!(
                        "conservation broken: {} - {} != {}",
                        stats.insertions,
                        stats.evictions,
                        front.len()
                    ));
                }
                for b in front.cached_blocks() {
                    if front.shard_of(b) != shard_of(b, shards) {
                        return Err(format!("{b} routed inconsistently"));
                    }
                }
                Ok(())
            },
        );
    }
}

/// Replaying a stream sequentially through the sharded front must be
/// indistinguishable from partitioning it by shard and replaying each
/// partition on its own scoped worker thread (shards are independent, and
/// each worker preserves its shard's request order).
#[test]
fn parallel_shard_replay_matches_sequential_replay() {
    let gen = CacheOpsGen { max_ops: 400, keyspace: 50, max_capacity: 16 };
    for shards in [2usize, 4] {
        forall(
            &Config { cases: 20, seed: 0x9A7A + shards as u64, ..Default::default() },
            &gen,
            |(ops, cap)| {
                let sequential = sharded("h-svm-lru", shards, *cap);
                for (t, (key, reuse)) in ops.iter().enumerate() {
                    sequential.access_or_insert(BlockId(*key), &ctx(t as u64, *reuse));
                }

                let parallel = sharded("h-svm-lru", shards, *cap);
                let mut parts: Vec<Vec<usize>> = vec![Vec::new(); shards];
                for (i, (key, _)) in ops.iter().enumerate() {
                    parts[shard_of(BlockId(*key), shards)].push(i);
                }
                let per_shard: Vec<ShardStats> = run_fanout(
                    shards,
                    |w| {
                        for &i in &parts[w] {
                            let (key, reuse) = ops[i];
                            parallel.access_or_insert(BlockId(key), &ctx(i as u64, reuse));
                        }
                        parallel.stats_of(w)
                    },
                    FanoutOptions::new(),
                )
                .into_workers();

                let mut merged = ShardStats::default();
                for s in &per_shard {
                    merged.merge(s);
                }
                if merged != parallel.stats() {
                    return Err("worker-returned stats disagree with merged stats".into());
                }
                if sequential.stats() != parallel.stats() {
                    return Err(format!(
                        "sequential {:?} vs parallel {:?}",
                        sequential.stats(),
                        parallel.stats()
                    ));
                }
                if sequential.cached_blocks() != parallel.cached_blocks() {
                    return Err("final cache contents diverge".into());
                }
                Ok(())
            },
        );
    }
}

/// The lock-split acceptance property: writer threads hammer one
/// `ShardedCache` while reader threads loop the lock-free stats path the
/// whole time. Every snapshot a reader takes must be internally
/// consistent — `hits + misses == requests` (merged and per shard),
/// `used() <= capacity()`, requests monotone — and the final merged
/// stats must equal a sequential replay of the same stream.
#[test]
fn concurrent_stats_readers_stay_consistent_with_writers() {
    let shards = 4usize;
    let capacity = 32u64;
    let ops: Vec<(u64, bool)> = {
        // Deterministic mixed stream: hot head + scattered tail.
        (0..6_000u64)
            .map(|t| {
                let key = if t % 3 == 0 { t % 7 } else { (t * 7919) % 96 };
                (key, key % 2 == 0)
            })
            .collect()
    };

    // Sequential ground truth (shards are independent, so the sequential
    // replay sees exactly the per-shard streams the workers will).
    let sequential = sharded("lru", shards, capacity);
    for (t, (key, reuse)) in ops.iter().enumerate() {
        sequential.access_or_insert(BlockId(*key), &ctx(t as u64, *reuse));
    }

    let concurrent = sharded("lru", shards, capacity);
    let mut parts: Vec<Vec<usize>> = vec![Vec::new(); shards];
    for (i, (key, _)) in ops.iter().enumerate() {
        parts[shard_of(BlockId(*key), shards)].push(i);
    }
    let concurrent_ref = &concurrent;
    let report = run_fanout(
        shards,
        |w| {
            for &i in &parts[w] {
                let (key, reuse) = ops[i];
                concurrent_ref.access_or_insert(BlockId(key), &ctx(i as u64, reuse));
            }
            concurrent_ref.stats_of(w)
        },
        FanoutOptions::new().monitor(|done: &std::sync::atomic::AtomicBool| {
            std::thread::scope(|scope| {
                let readers: Vec<_> = (0..3)
                    .map(|_| {
                        scope.spawn(move || {
                            let mut snapshots = 0u64;
                            let mut last_requests = 0u64;
                            // do-while: at least one snapshot even if the
                            // workers win the race outright.
                            loop {
                                let merged = concurrent_ref.stats();
                                assert_eq!(
                                    merged.hits + merged.misses,
                                    merged.requests,
                                    "torn merged snapshot"
                                );
                                assert!(
                                    merged.requests >= last_requests,
                                    "merged requests went backwards"
                                );
                                last_requests = merged.requests;
                                assert!(
                                    concurrent_ref.used() <= concurrent_ref.capacity(),
                                    "occupancy over capacity"
                                );
                                for s in 0..shards {
                                    let snap = concurrent_ref.snapshot_of(s);
                                    assert_eq!(
                                        snap.stats.hits + snap.stats.misses,
                                        snap.stats.requests,
                                        "torn shard snapshot"
                                    );
                                    assert_eq!(
                                        snap.stats.insertions - snap.stats.evictions,
                                        snap.blocks,
                                        "counters and occupancy decoupled"
                                    );
                                }
                                snapshots += 1;
                                if done.load(std::sync::atomic::Ordering::Acquire) {
                                    break;
                                }
                            }
                            snapshots
                        })
                    })
                    .collect();
                readers
                    .into_iter()
                    .map(|h| h.join().expect("stats reader panicked"))
                    .sum::<u64>()
            })
        }),
    );
    let reader_stats = report.monitor.expect("monitor configured");
    let per_shard: Vec<ShardStats> =
        report.workers.into_iter().map(|r| r.expect("worker panicked")).collect();
    assert!(reader_stats > 0, "readers must have snapshotted mid-replay");

    let mut merged = ShardStats::default();
    for s in &per_shard {
        merged.merge(s);
    }
    assert_eq!(merged, concurrent.stats(), "worker-held stats disagree with merged");
    assert_eq!(merged.requests, ops.len() as u64);
    assert_eq!(
        concurrent.stats(),
        sequential.stats(),
        "final merged stats must equal the sequential replay"
    );
    assert_eq!(concurrent.cached_blocks(), sequential.cached_blocks());
    assert_eq!(concurrent.used(), sequential.used());
}

/// The one-PR deprecation contract: every `#[deprecated]` constructor
/// shim must stay bit-identical to its `CacheBuilder` replacement until
/// the shims are dropped. This file is the designated home of those pins;
/// everywhere else `#[allow(deprecated)]` is a lint violation.
#[test]
#[allow(deprecated)]
fn deprecated_sharded_constructor_shims_match_the_builder() {
    use h_svm_lru::cache::admission::make_admission;

    let ops: Vec<(u64, bool)> =
        (0..600u64).map(|t| ((t * 7919 + t % 13) % 48, t % 2 == 0)).collect();
    let drive = |cache: &ShardedCache| {
        for (t, (key, reuse)) in ops.iter().enumerate() {
            cache.access_or_insert(BlockId(*key), &ctx(t as u64, *reuse));
        }
        (cache.stats(), cache.cached_blocks(), cache.used())
    };

    let old = ShardedCache::from_registry("h-svm-lru", 4, 16).expect("registry policy");
    assert_eq!(drive(&old), drive(&sharded("h-svm-lru", 4, 16)), "from_registry");

    let old = ShardedCache::from_registry_with_admission("lru", "tinylfu", 2, 12)
        .expect("registry names");
    let new = CacheBuilder::new()
        .policy("lru")
        .admission("tinylfu")
        .shards(2)
        .capacity(12)
        .build()
        .unwrap();
    assert_eq!(drive(&old), drive(&new), "from_registry_with_admission");

    let policies = || (0..3).map(|_| make_policy("lru").unwrap()).collect::<Vec<_>>();
    let old = ShardedCache::new(policies(), 9);
    let new = CacheBuilder::new()
        .policy_with(|| make_policy("lru").unwrap())
        .shards(3)
        .capacity(9)
        .build()
        .unwrap();
    assert_eq!(drive(&old), drive(&new), "ShardedCache::new");

    let admissions = (0..3).map(|_| make_admission("ghost").unwrap()).collect::<Vec<_>>();
    let old = ShardedCache::with_admission(policies(), admissions, 9);
    let new = CacheBuilder::new()
        .policy_with(|| make_policy("lru").unwrap())
        .admission_with(|| make_admission("ghost").unwrap())
        .shards(3)
        .capacity(9)
        .build()
        .unwrap();
    assert_eq!(drive(&old), drive(&new), "ShardedCache::with_admission");
}

/// Same contract for the single-shard front: the deprecated
/// `BlockCache::with_admission` must match `build_block_cache`.
#[test]
#[allow(deprecated)]
fn deprecated_block_cache_shim_matches_the_builder() {
    use h_svm_lru::cache::admission::make_admission;

    let mut old = BlockCache::with_admission(
        make_policy("lru").unwrap(),
        make_admission("tinylfu").unwrap(),
        8,
    );
    let mut new = CacheBuilder::new()
        .policy("lru")
        .admission("tinylfu")
        .capacity(8)
        .build_block_cache()
        .unwrap();
    for t in 0..600u64 {
        let key = BlockId((t * 7919 + t % 13) % 48);
        let c = ctx(t, t % 2 == 0);
        assert_eq!(old.access_or_insert(key, &c), new.access_or_insert(key, &c));
    }
    assert_eq!(old.cached_blocks(), new.cached_blocks());
    assert_eq!(old.used(), new.used());
}

/// The shard router: total (every block routed), stable, in range, and
/// degenerate for a single shard.
#[test]
fn shard_routing_is_total_stable_and_uniformish() {
    for n in [1usize, 2, 3, 8, 16] {
        let mut counts = vec![0u64; n];
        for id in 0..4096u64 {
            let s = shard_of(BlockId(id), n);
            assert!(s < n, "shard {s} out of range for n={n}");
            assert_eq!(s, shard_of(BlockId(id), n), "routing must be stable");
            counts[s] += 1;
        }
        if n == 1 {
            assert_eq!(counts[0], 4096);
        } else {
            // Fibonacci mix over sequential ids: no shard may be starved or
            // hold a wildly disproportionate share.
            let expect = 4096 / n as u64;
            for (s, &c) in counts.iter().enumerate() {
                assert!(
                    c > expect / 2 && c < expect * 2,
                    "shard {s}/{n} holds {c} of 4096 (expect ~{expect})"
                );
            }
        }
    }
}
