//! Property tests for the DAG replay path (`experiments::dag_replay`).
//!
//! Two invariants (see docs/ARCHITECTURE.md, "CI-enforced invariants"):
//!
//! 1. **Determinism** — the replay runs entirely on the simulated clock
//!    with seeded placement, so the same (policy, seed, shard count)
//!    must reproduce bit-identical job-time totals and cache counters,
//!    at 1 shard and at 8.
//! 2. **Monotonicity in capacity** — a finite cache can only add
//!    recompute charges on top of what an effectively infinite cache
//!    pays; it must never finish the suite *faster*.

use h_svm_lru::config::ClusterConfig;
use h_svm_lru::experiments::run_dag_pass;
use h_svm_lru::util::bytes::GB;
use h_svm_lru::workload::dag::{chain_suite, diamond_suite, DagJob};

fn cfg() -> ClusterConfig {
    ClusterConfig {
        datanodes: 5,
        replication: 2,
        ..Default::default()
    }
}

fn suites() -> Vec<(&'static str, Vec<DagJob>)> {
    vec![
        ("diamond", diamond_suite(3, 4, 8)),
        ("chain", chain_suite(2, 4)),
    ]
}

#[test]
fn same_seed_reproduces_identical_totals() {
    let cfg = cfg();
    for (name, jobs) in suites() {
        for &shards in &[1usize, 8] {
            for &seed in &[7u64, 42] {
                let capacity = 16 * cfg.block_size;
                let (a, log_a) =
                    run_dag_pass("lru", &cfg, shards, capacity, &jobs, seed, &[]).unwrap();
                let (b, log_b) =
                    run_dag_pass("lru", &cfg, shards, capacity, &jobs, seed, &[]).unwrap();
                assert_eq!(
                    a.total_job_time_s.to_bits(),
                    b.total_job_time_s.to_bits(),
                    "{name}: job-time totals diverged at shards={shards} seed={seed}"
                );
                assert_eq!(
                    a.makespan_s.to_bits(),
                    b.makespan_s.to_bits(),
                    "{name}: makespan diverged at shards={shards} seed={seed}"
                );
                assert_eq!(a.stats.requests, b.stats.requests, "{name}");
                assert_eq!(a.stats.hits, b.stats.hits, "{name}");
                assert_eq!(a.stats.evictions, b.stats.evictions, "{name}");
                assert_eq!(a.recompute_events, b.recompute_events, "{name}");
                assert_eq!(
                    a.recompute_seconds.to_bits(),
                    b.recompute_seconds.to_bits(),
                    "{name}"
                );
                assert_eq!(log_a.len(), log_b.len(), "{name}: access logs diverged");
                for (ra, rb) in log_a.iter().zip(log_b.iter()) {
                    assert_eq!(ra.block, rb.block, "{name}: access order diverged");
                }
            }
        }
    }
}

#[test]
fn finite_cache_never_beats_infinite_cache() {
    let cfg = cfg();
    for (name, jobs) in suites() {
        let (infinite, _) = run_dag_pass("lru", &cfg, 1, 1024 * GB, &jobs, 7, &[]).unwrap();
        assert_eq!(
            infinite.recompute_events, 0,
            "{name}: an infinite cache must never recompute"
        );
        for &blocks in &[4u64, 8, 16, 64] {
            let (finite, _) =
                run_dag_pass("lru", &cfg, 1, blocks * cfg.block_size, &jobs, 7, &[]).unwrap();
            assert!(
                finite.total_job_time_s >= infinite.total_job_time_s,
                "{name}: {blocks}-block cache finished in {} s, beating the \
                 infinite cache's {} s",
                finite.total_job_time_s,
                infinite.total_job_time_s,
            );
            assert!(
                finite.makespan_s >= infinite.makespan_s,
                "{name}: finite-cache makespan beat infinite at {blocks} blocks"
            );
        }
    }
}
