//! Property tests over the SVM layer: SMO dual feasibility, feature
//! normalization, labeling totality, dataset plumbing.

use h_svm_lru::cache::CacheAffinity;
use h_svm_lru::hdfs::{BlockId, BlockKind};
use h_svm_lru::mapreduce::job::JobStatus;
use h_svm_lru::mapreduce::task::TaskStatus;
use h_svm_lru::sim::SimTime;
use h_svm_lru::svm::dataset::{pad, Dataset};
use h_svm_lru::svm::features::{BlockStatsTracker, N_FEATURES};
use h_svm_lru::svm::kernel::{KernelKind, KernelParams};
use h_svm_lru::svm::labeling::label;
use h_svm_lru::svm::smo::{train, SmoConfig};
use h_svm_lru::testkit::{forall, Config, Gen};
use h_svm_lru::util::bytes::MB;
use h_svm_lru::util::rng::Pcg64;

/// Generator: random two-class datasets with varying separation.
struct DatasetGen;

impl Gen for DatasetGen {
    type Value = (Vec<([f32; N_FEATURES], bool)>, u64);

    fn generate(&self, rng: &mut Pcg64) -> Self::Value {
        let n_per = 5 + rng.gen_range(40) as usize;
        let gap = rng.gen_f64_range(0.05, 0.5);
        let sigma = rng.gen_f64_range(0.02, 0.15);
        let mut rows = Vec::new();
        for _ in 0..n_per {
            let mut a = [0.0f32; N_FEATURES];
            let mut b = [0.0f32; N_FEATURES];
            for k in 0..N_FEATURES {
                a[k] = rng.gen_normal(0.5 - gap, sigma) as f32;
                b[k] = rng.gen_normal(0.5 + gap, sigma) as f32;
            }
            rows.push((a, true));
            rows.push((b, false));
        }
        (rows, rng.next_u64())
    }

    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let (rows, seed) = value;
        if rows.len() > 4 {
            vec![(rows[..rows.len() / 2].to_vec(), *seed)]
        } else {
            Vec::new()
        }
    }
}

#[test]
fn smo_dual_feasibility_on_random_datasets() {
    forall(&Config { cases: 25, ..Default::default() }, &DatasetGen, |(rows, _)| {
        let mut ds = Dataset::new();
        for (x, y) in rows {
            ds.push(*x, *y);
        }
        let cfg = SmoConfig::default();
        for kind in [KernelKind::Linear, KernelKind::Rbf] {
            let model = train(&ds, KernelParams::new(kind), &cfg);
            for &a in &model.alpha {
                if !(-1e-5..=cfg.c + 1e-5).contains(&a) {
                    return Err(format!("{kind:?}: alpha {a} outside [0, C]"));
                }
            }
            if !model.bias.is_finite() {
                return Err(format!("{kind:?}: non-finite bias"));
            }
            // Decisions must be finite for arbitrary queries.
            let s = model.decision(&[0.5; N_FEATURES]);
            if !s.is_finite() {
                return Err(format!("{kind:?}: non-finite decision {s}"));
            }
        }
        Ok(())
    });
}

#[test]
fn smo_learns_separable_data() {
    forall(&Config { cases: 15, seed: 0x51, ..Default::default() }, &DatasetGen, |(rows, _)| {
        // Only check well-separated datasets (gap baked into generator can
        // be small; filter by empirical margin).
        let mean_pos: f32 = rows.iter().filter(|(_, y)| *y).map(|(x, _)| x[0]).sum::<f32>()
            / rows.iter().filter(|(_, y)| *y).count() as f32;
        let mean_neg: f32 = rows.iter().filter(|(_, y)| !*y).map(|(x, _)| x[0]).sum::<f32>()
            / rows.iter().filter(|(_, y)| !*y).count() as f32;
        if (mean_pos - mean_neg).abs() < 0.3 {
            return Ok(()); // not separable enough to assert accuracy
        }
        let mut ds = Dataset::new();
        for (x, y) in rows {
            ds.push(*x, *y);
        }
        let model = train(&ds, KernelParams::new(KernelKind::Rbf), &SmoConfig::default());
        let acc = rows
            .iter()
            .filter(|(x, y)| model.predict(x) == *y)
            .count() as f64
            / rows.len() as f64;
        if acc < 0.9 {
            return Err(format!("separable data but acc={acc}"));
        }
        Ok(())
    });
}

#[test]
fn features_always_normalized() {
    // Whatever the access history, every feature stays in [0, 1].
    struct HistoryGen;
    impl Gen for HistoryGen {
        type Value = Vec<(u64, u64, u64)>; // (block, app, time_ms)
        fn generate(&self, rng: &mut Pcg64) -> Self::Value {
            let n = rng.gen_range(200) as usize;
            let mut t = 0u64;
            (0..n)
                .map(|_| {
                    t += rng.gen_range(10_000);
                    (rng.gen_range(20), rng.gen_range(6), t)
                })
                .collect()
        }
    }
    forall(&Config { cases: 40, ..Default::default() }, &HistoryGen, |history| {
        let mut tracker = BlockStatsTracker::new(128 * MB);
        for &(block, app, t_ms) in history {
            let now = SimTime(t_ms * 1000);
            for kind in [BlockKind::Input, BlockKind::Intermediate, BlockKind::Output] {
                for aff in [CacheAffinity::Low, CacheAffinity::Medium, CacheAffinity::High] {
                    let f =
                        tracker.features(BlockId(block), kind, 64 * MB, aff, 0.5, now);
                    for (i, v) in f.iter().enumerate() {
                        if !(0.0..=1.0).contains(v) || !v.is_finite() {
                            return Err(format!("feature {i} = {v} out of [0,1]"));
                        }
                    }
                }
            }
            tracker.record_access(BlockId(block), app, now);
        }
        Ok(())
    });
}

#[test]
fn labeling_is_total_and_consistent() {
    // Every (job, map, reduce) state combination must label without panic,
    // and terminal/failed jobs always produce (false, false).
    let jobs = [
        JobStatus::New,
        JobStatus::Initiated,
        JobStatus::Running,
        JobStatus::Succeeded,
        JobStatus::Failed,
        JobStatus::Killed,
        JobStatus::Error,
    ];
    let tasks = [
        TaskStatus::New,
        TaskStatus::Scheduled,
        TaskStatus::Running,
        TaskStatus::Succeeded,
        TaskStatus::Failed,
        TaskStatus::Killed,
    ];
    for job in jobs {
        for map in tasks {
            for reduce in std::iter::once(None).chain(tasks.into_iter().map(Some)) {
                let l = label(job, map, reduce);
                if matches!(job, JobStatus::Failed | JobStatus::Killed | JobStatus::Error)
                    && (l.map_input_reused || l.reduce_input_reused)
                {
                    panic!("failed job must not mark reuse: {job:?} {map:?} {reduce:?}");
                }
                if job == JobStatus::Succeeded && (l.map_input_reused || l.reduce_input_reused) {
                    panic!("completed job must not mark reuse (Table 4 row 10)");
                }
            }
        }
    }
}

#[test]
fn padding_roundtrip_preserves_rows() {
    struct SizeGen;
    impl Gen for SizeGen {
        type Value = (usize, usize);
        fn generate(&self, rng: &mut Pcg64) -> Self::Value {
            (rng.gen_range(300) as usize, 1 + rng.gen_range(300) as usize)
        }
    }
    forall(&Config { cases: 60, ..Default::default() }, &SizeGen, |&(rows, pad_to)| {
        let mut ds = Dataset::new();
        for i in 0..rows {
            ds.push([i as f32 / 300.0; N_FEATURES], i % 3 == 0);
        }
        let p = pad(&ds, pad_to);
        let expect_real = rows.min(pad_to);
        if p.n_real != expect_real {
            return Err(format!("n_real {} != {expect_real}", p.n_real));
        }
        if p.mask.iter().map(|&m| m as usize).sum::<usize>() != expect_real {
            return Err("mask sum mismatch".into());
        }
        // Real rows round-trip bit-exactly.
        for i in 0..expect_real {
            let row = &p.x[i * N_FEATURES..(i + 1) * N_FEATURES];
            if row != ds.x[i] {
                return Err(format!("row {i} corrupted"));
            }
            let want_y = ds.y[i];
            if p.y[i] != want_y {
                return Err(format!("label {i} corrupted"));
            }
        }
        // Padding rows are inert.
        for i in expect_real..pad_to {
            if p.y[i] != 0.0 || p.mask[i] != 0.0 {
                return Err("padding not neutral".into());
            }
        }
        Ok(())
    });
}
