//! Property tests for the fault-injection layer and the classifier
//! circuit breaker: the scripted state-machine walk, retry-budget and
//! cold-query conservation, breaker invariants under random fail/heal
//! scripts, the all-clear + breaker-off parity guarantee, and the
//! acceptance criterion that a chaos replay exports byte-identical
//! metrics JSONL under the same seed and plan.

use anyhow::{bail, Result};
use h_svm_lru::coordinator::{
    BatcherConfig, BatcherProbe, BreakerConfig, BreakerState, ShardBatcher, TrainerConfig,
};
use h_svm_lru::experiments::chaos::{breaker_for_trace, default_serving_plan, run_serving_chaos};
use h_svm_lru::cache::RecencyConfig;
use h_svm_lru::experiments::online_sharded::{run_online, TrainerMode};
use h_svm_lru::hdfs::BlockId;
use h_svm_lru::obs::{MetricsRegistry, RunObservations, DEFAULT_WINDOW_US};
use h_svm_lru::runtime::SvmBackend;
use h_svm_lru::sim::{FaultInjector, FaultPlan, SimDuration, SimTime};
use h_svm_lru::svm::dataset::Dataset;
use h_svm_lru::svm::features::FeatureVec;
use h_svm_lru::svm::KernelKind;
use h_svm_lru::testkit::{forall, Config, VecU64Gen};
use h_svm_lru::util::bytes::MB;
use h_svm_lru::workload::fig3_trace;

/// Scriptable backend: healthy it classifies `f[0] > 0.5`, failing it
/// errors every `decision_batch` — the toggle drives the breaker walk.
struct FlakyBackend {
    fail: bool,
    calls: u64,
}

impl SvmBackend for FlakyBackend {
    fn name(&self) -> &'static str {
        "flaky"
    }

    fn train(&mut self, _ds: &Dataset) -> Result<()> {
        Ok(())
    }

    fn decision_batch(&mut self, queries: &[FeatureVec]) -> Result<Vec<f32>> {
        self.calls += 1;
        if self.fail {
            bail!("scripted backend failure");
        }
        Ok(queries.iter().map(|f| if f[0] > 0.5 { 1.0 } else { -1.0 }).collect())
    }

    fn is_trained(&self) -> bool {
        true
    }
}

fn fv(v: f32) -> FeatureVec {
    let mut f = FeatureVec::default();
    f[0] = v;
    f
}

/// The full breaker walk, scripted: Closed → (threshold failures) → Open
/// → fallback without a backend call → HalfOpen probe that fails and
/// re-opens → a later probe that succeeds and closes. Every transition is
/// observable through `breaker_state()` and the probe counters.
#[test]
fn breaker_walks_closed_open_halfopen_and_back() {
    let probe = BatcherProbe::new();
    let breaker = BreakerConfig {
        failure_threshold: 2,
        max_retries: 0, // one backend call per flush — exact call accounting
        probe_after: SimDuration::from_micros(1_000),
        ..BreakerConfig::on()
    };
    let cfg = BatcherConfig { queue_depth: 1, breaker, ..BatcherConfig::default() };
    let mut b = ShardBatcher::with_probe(cfg, probe.clone());
    let mut be = FlakyBackend { fail: false, calls: 0 };

    assert_eq!(b.breaker_state(), Some(BreakerState::Closed));

    // Healthy inline flush (queue_depth 1): the caller gets its class.
    let got = b.predict(&mut be, BlockId(1), 0, fv(0.9), SimTime(0)).unwrap();
    assert_eq!(got, Some(true));
    assert_eq!(b.breaker_state(), Some(BreakerState::Closed));

    // Two consecutive flush failures cross the threshold and open it.
    be.fail = true;
    assert_eq!(b.predict(&mut be, BlockId(2), 0, fv(0.9), SimTime(10)).unwrap(), None);
    assert_eq!(b.breaker_state(), Some(BreakerState::Closed), "one failure is below threshold");
    assert_eq!(b.predict(&mut be, BlockId(3), 0, fv(0.9), SimTime(20)).unwrap(), None);
    assert_eq!(b.breaker_state(), Some(BreakerState::Open));
    assert_eq!(probe.breaker_opens(), 1);

    // Open: the cold query falls back without touching the backend.
    let calls_before = be.calls;
    assert_eq!(b.predict(&mut be, BlockId(4), 0, fv(0.9), SimTime(30)).unwrap(), None);
    assert_eq!(be.calls, calls_before, "open breaker must not call the backend");
    assert_eq!(probe.breaker_fallbacks(), 1);
    assert_eq!(b.breaker_state(), Some(BreakerState::Open));

    // Past the probe cadence a still-failing probe re-opens immediately
    // (HalfOpen needs no threshold).
    assert_eq!(b.predict(&mut be, BlockId(5), 0, fv(0.9), SimTime(1_100)).unwrap(), None);
    assert_eq!(be.calls, calls_before + 1, "the probe is exactly one backend call");
    assert_eq!(b.breaker_state(), Some(BreakerState::Open));
    assert_eq!(probe.breaker_opens(), 2);

    // The re-open restarted the probe clock: shortly after, fall back.
    assert_eq!(b.predict(&mut be, BlockId(6), 0, fv(0.9), SimTime(1_150)).unwrap(), None);
    assert_eq!(probe.breaker_fallbacks(), 2);

    // A healthy probe past the cadence closes the breaker and serves.
    be.fail = false;
    let got = b.predict(&mut be, BlockId(7), 0, fv(0.9), SimTime(2_200)).unwrap();
    assert_eq!(got, Some(true));
    assert_eq!(b.breaker_state(), Some(BreakerState::Closed));
    assert_eq!(probe.breaker_closes(), 1);

    // Closed again: normal service.
    let got = b.predict(&mut be, BlockId(8), 0, fv(0.9), SimTime(2_300)).unwrap();
    assert_eq!(got, Some(true));
    assert_eq!(be.calls, 6, "1 healthy + 2 failures + 2 probes + 1 healthy");
}

/// Retry accounting: a persistently failing flush makes exactly
/// `1 + max_retries` backend calls, tallies `max_retries` retries and
/// charges `retries × retry_backoff` of simulated backoff — and the
/// cold-query ledger stays conserved (`cold == flushed + dropped`).
#[test]
fn retry_budget_is_conserved_and_charged() {
    for budget in [1u32, 3] {
        let probe = BatcherProbe::new();
        let breaker = BreakerConfig {
            failure_threshold: 1_000_000, // stay Closed: every flush hits the backend
            max_retries: budget,
            retry_backoff: SimDuration::from_micros(500),
            ..BreakerConfig::on()
        };
        let cfg = BatcherConfig { queue_depth: 1, breaker, ..BatcherConfig::default() };
        let mut b = ShardBatcher::with_probe(cfg, probe.clone());
        let mut be = FlakyBackend { fail: true, calls: 0 };

        let queries = 5u64;
        for i in 0..queries {
            let got = b.predict(&mut be, BlockId(i), 0, fv(0.9), SimTime(i * 10)).unwrap();
            assert_eq!(got, None, "failed flushes serve the unclassified fallback");
        }
        b.flush(&mut be).unwrap(); // empty queue: a no-op for every counter

        assert_eq!(be.calls, queries * (1 + budget as u64), "1 + budget calls per flush");
        assert_eq!(probe.retries(), queries * budget as u64);
        assert_eq!(probe.retry_backoff_us(), probe.retries() * 500);
        assert_eq!(probe.cold_queries(), queries);
        assert_eq!(probe.flushed_queries(), 0);
        assert_eq!(probe.dropped(), queries, "failed queries are accounted, not leaked");
        assert_eq!(probe.cold_queries(), probe.flushed_queries() + probe.dropped());
        assert_eq!(b.breaker_state(), Some(BreakerState::Closed), "below threshold");
    }
}

/// One scripted fail/heal walk; returns every probe counter, the final
/// breaker state and the backend call count — the whole observable
/// surface, so equality across two runs is behavioral determinism.
fn run_breaker_script(script: &[u64]) -> (Vec<u64>, Option<BreakerState>, u64) {
    let probe = BatcherProbe::new();
    let breaker = BreakerConfig {
        failure_threshold: 2,
        max_retries: 1,
        probe_after: SimDuration::from_micros(500),
        ..BreakerConfig::on()
    };
    let cfg = BatcherConfig { queue_depth: 1, breaker, ..BatcherConfig::default() };
    let mut b = ShardBatcher::with_probe(cfg, probe.clone());
    let mut be = FlakyBackend { fail: false, calls: 0 };
    let mut now = 0u64;
    for (i, &v) in script.iter().enumerate() {
        be.fail = v & 1 == 1;
        now += (v >> 1) % 3_000;
        // Fresh block per step: no class-cache hits, every step is a cold
        // query. With the breaker active a backend error never surfaces.
        let _ = b
            .predict(&mut be, BlockId(i as u64), 0, fv(0.9), SimTime(now))
            .expect("active breaker swallows backend errors");
    }
    b.flush(&mut be).expect("end-of-run flush of an empty queue");
    let counters = vec![
        probe.cold_queries(),
        probe.flushed_queries(),
        probe.dropped(),
        probe.breaker_opens(),
        probe.breaker_closes(),
        probe.breaker_fallbacks(),
        probe.retries(),
        probe.retry_backoff_us(),
    ];
    (counters, b.breaker_state(), be.calls)
}

/// Invariants under arbitrary fail/heal scripts: the cold-query ledger is
/// conserved, closes never outnumber opens, fallbacks are bounded by the
/// query count, and the whole observable surface is a pure function of
/// the script (replaying it yields identical counters and state).
#[test]
fn breaker_invariants_hold_under_random_scripts() {
    let gen = VecU64Gen { min_len: 1, max_len: 200, max_value: u64::MAX };
    forall(&Config { cases: 40, seed: 0xFA17, ..Default::default() }, &gen, |script| {
        let (counters, state, calls) = run_breaker_script(script);
        let [cold, flushed, dropped, opens, closes, fallbacks, ..] = counters[..] else {
            return Err("counter vector shape changed".into());
        };
        if cold != flushed + dropped {
            return Err(format!(
                "ledger leak: cold {cold} != flushed {flushed} + dropped {dropped}"
            ));
        }
        if closes > opens {
            return Err(format!("{closes} closes but only {opens} opens"));
        }
        if fallbacks + cold != script.len() as u64 {
            return Err(format!(
                "every query is either enqueued or a fallback: {fallbacks} + {cold} != {}",
                script.len()
            ));
        }
        if run_breaker_script(script) != (counters.clone(), state, calls) {
            return Err("same script, different counters: breaker walk is not deterministic".into());
        }
        Ok(())
    });
}

/// The parity guarantee behind the whole PR: an all-clear fault plan plus
/// a disabled breaker must replay bit-identically to the fault-free
/// frozen path — across seeds and shard counts.
#[test]
fn all_clear_plan_with_breaker_off_is_bit_identical_to_fault_free() {
    for seed in [5u64, 11] {
        let trace = fig3_trace(64 * MB, seed);
        for shards in [1usize, 8] {
            let baseline = run_online(
                "h-svm-lru",
                shards,
                8 * 64 * MB,
                &trace,
                TrainerMode::Frozen,
                KernelKind::Rbf,
                TrainerConfig::default(),
                BatcherConfig::default(),
                RecencyConfig::default(),
            )
            .expect("fault-free frozen replay");
            let injector = FaultInjector::new(FaultPlan::all_clear(seed));
            let registry = MetricsRegistry::disabled();
            let under = run_serving_chaos(
                "h-svm-lru",
                shards,
                8 * 64 * MB,
                &trace,
                KernelKind::Rbf,
                BreakerConfig::off(),
                &injector,
                &registry,
                DEFAULT_WINDOW_US,
                RecencyConfig::default(),
            )
            .expect("all-clear chaos replay");
            assert_eq!(
                under.stats, baseline.stats,
                "all-clear + breaker-off diverged at seed {seed}, {shards} shard(s)"
            );
            assert_eq!(under.breaker_opens, 0);
            assert_eq!(under.breaker_fallbacks, 0);
            assert_eq!(injector.backend_failures(), 0, "all-clear plan injected a fault");
            assert_eq!(injector.backend_slowdowns(), 0);
        }
    }
}

/// The chaos acceptance criterion: two same-seed serving-arm chaos
/// replays — same plan, same breaker, outage and all — export
/// byte-identical metrics JSONL, at one shard and at eight.
#[test]
fn same_seed_chaos_runs_export_byte_identical_jsonl() {
    let trace = fig3_trace(64 * MB, 11);
    for shards in [1usize, 8] {
        let render = || {
            let registry = MetricsRegistry::new();
            let injector = FaultInjector::new(default_serving_plan(&trace, 11));
            injector.register_gauges(&registry, "faults");
            let report = run_serving_chaos(
                "h-svm-lru",
                shards,
                8 * 64 * MB,
                &trace,
                KernelKind::Rbf,
                breaker_for_trace(&trace),
                &injector,
                &registry,
                DEFAULT_WINDOW_US,
                RecencyConfig::default(),
            )
            .expect("chaos replay");
            let obs = RunObservations {
                windows: report.windows.clone(),
                audit: Vec::new(),
                audit_seen: 0,
                audit_every: 1,
            };
            let mut doc = obs.into_doc(DEFAULT_WINDOW_US);
            doc.meta_str("cmd", "chaos-property");
            doc.meta_str("policy", "h-svm-lru");
            doc.meta_u64("shards", shards as u64);
            doc.meta_u64("seed", 11);
            doc.meta_u64("requests", report.stats.requests);
            doc.meta_u64("breaker_opens", report.breaker_opens);
            doc.to_jsonl(&registry)
        };
        let first = render();
        let second = render();
        assert_eq!(first, second, "same-seed chaos JSONL differs at {shards} shard(s)");
        assert!(first.contains("\"name\":\"batcher.breaker_opens\""), "breaker gauges exported");
        assert!(first.contains("\"name\":\"faults.backend_failures\""), "injector gauges exported");
        assert!(first.contains("\"type\":\"window\""));
    }
}
