//! Cross-policy integration: every registered replacement policy driven
//! through the full coordinator on the shared trace, plus targeted
//! semantic checks that separate the strategies from each other.

use h_svm_lru::cache::registry::{make_policy, POLICY_NAMES};
use h_svm_lru::cache::{AccessContext, BlockCache};
use h_svm_lru::config::SvmConfig;
use h_svm_lru::experiments::common::provision_fig3_cluster;
use h_svm_lru::experiments::{make_coordinator, policies, replay_trace_two_pass, Scenario};
use h_svm_lru::hdfs::BlockId;
use h_svm_lru::sim::SimTime;
use h_svm_lru::util::bytes::MB;
use h_svm_lru::workload::fig3_trace;

fn svm_rust() -> SvmConfig {
    SvmConfig { backend: "rust".into(), ..Default::default() }
}

#[test]
fn ablation_runs_every_policy() {
    let results = policies::run(&svm_rust(), 11, 8).expect("ablation");
    assert_eq!(results.len(), POLICY_NAMES.len());
    for r in &results {
        assert!(r.hit_ratio > 0.0, "{} never hit", r.policy);
        assert!(r.hit_ratio < 1.0, "{} impossibly perfect", r.policy);
    }
}

#[test]
fn hsvmlru_wins_the_pollution_trace() {
    // On the paper's own workload shape (hot inputs + single-pass
    // pollution), the learned policy must beat the recency/FIFO family.
    let results = policies::run(&svm_rust(), 11, 8).expect("ablation");
    let get = |n: &str| results.iter().find(|r| r.policy == n).unwrap().hit_ratio;
    let hsvm = get("h-svm-lru");
    assert!(hsvm > get("lru"), "h-svm-lru {hsvm} vs lru {}", get("lru"));
    assert!(hsvm > get("fifo"), "h-svm-lru {hsvm} vs fifo {}", get("fifo"));
}

#[test]
fn frequency_policies_beat_recency_on_zipf_pollution() {
    // LFU-family should also beat plain LRU here (frequency is a good
    // signal against single-pass pollution) — sanity that the baselines
    // are faithful, not strawmen.
    let results = policies::run(&svm_rust(), 11, 8).expect("ablation");
    let get = |n: &str| results.iter().find(|r| r.policy == n).unwrap().hit_ratio;
    assert!(get("lfu") > get("fifo"), "lfu should beat fifo");
    assert!(get("exd") >= get("fifo"), "exd should be >= fifo");
}

#[test]
fn every_policy_survives_trace_replay_through_coordinator() {
    for &name in POLICY_NAMES {
        let (_cfg, cluster) = provision_fig3_cluster(64 * MB, 6, 13);
        let scenario = if name == "h-svm-lru" {
            Scenario::SvmLru
        } else {
            Scenario::Policy(name.to_string())
        };
        let mut coord = make_coordinator(cluster, &scenario, &svm_rust())
            .unwrap_or_else(|e| panic!("{name}: {e:#}"));
        let trace = fig3_trace(64 * MB, 13);
        let hr = replay_trace_two_pass(&mut coord, &trace)
            .unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert!((0.0..1.0).contains(&hr), "{name}: hit ratio {hr}");
        assert_eq!(
            coord.process_cache_reports(),
            0,
            "{name}: metadata drift after replay"
        );
    }
}

#[test]
fn policies_differ_on_discriminating_streams() {
    // A stream engineered so LRU, LFU and FIFO choose different victims:
    // proves the implementations are genuinely distinct orderings.
    let mut lru = BlockCache::new(make_policy("lru").unwrap(), 3);
    let mut lfu = BlockCache::new(make_policy("lfu").unwrap(), 3);
    let mut fifo = BlockCache::new(make_policy("fifo").unwrap(), 3);
    let seq: &[u64] = &[1, 2, 3, 1, 1, 2, 4]; // insert 4 forces an eviction
    let mut evictions = Vec::new();
    for cache in [&mut lru, &mut lfu, &mut fifo] {
        let mut ev = Vec::new();
        for (t, &b) in seq.iter().enumerate() {
            let out = cache.access_or_insert(
                BlockId(b),
                &AccessContext::simple(SimTime(t as u64), 1),
            );
            ev.extend(out.evicted);
        }
        evictions.push(ev);
    }
    // LRU evicts 3 (least recent), LFU evicts 3 (least frequent),
    // FIFO evicts 1 (first in).
    assert_eq!(evictions[0], vec![BlockId(3)], "lru victim");
    assert_eq!(evictions[1], vec![BlockId(3)], "lfu victim");
    assert_eq!(evictions[2], vec![BlockId(1)], "fifo victim");
}

#[test]
fn byte_hit_ratio_tracks_hit_ratio_for_uniform_blocks() {
    // The paper notes hit ratio == byte hit ratio when blocks are equal
    // size; our trace uses uniform blocks, so the two must coincide.
    let results = policies::run(&svm_rust(), 17, 10).expect("ablation");
    for r in &results {
        assert!(
            (r.hit_ratio - r.byte_hit_ratio).abs() < 1e-9,
            "{}: hit {} vs byte-hit {}",
            r.policy,
            r.hit_ratio,
            r.byte_hit_ratio
        );
    }
}
