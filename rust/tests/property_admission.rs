//! Property tests for the admission subsystem: the Count-Min sketch never
//! underestimates, the doorkeeper reset is sound (no stale membership),
//! `always` admission is bit-identical to the pre-admission cache for every
//! replacement policy, the ghost cache respects its capacity bound, and
//! every (policy, admission) pairing preserves the cache invariants.

use h_svm_lru::cache::admission::{
    Doorkeeper, FrequencySketch, GhostProbation, ADMISSION_NAMES,
};
use h_svm_lru::cache::registry::{make_policy, POLICY_NAMES};
use h_svm_lru::cache::{AccessContext, AdmissionPolicy, BlockCache, CacheBuilder};
use h_svm_lru::hdfs::BlockId;
use h_svm_lru::sim::SimTime;
use h_svm_lru::testkit::{forall, CacheOpsGen, Config, Gen, VecU64Gen};

fn ctx(t: u64, reuse: bool) -> AccessContext {
    AccessContext::simple(SimTime(t), 1).with_prediction(reuse)
}

/// A Count-Min sketch may overestimate (hash collisions) but must never
/// underestimate a key's true count below the 4-bit saturation point.
#[test]
fn sketch_never_underestimates() {
    let gen = VecU64Gen { min_len: 1, max_len: 400, max_value: 64 };
    forall(&Config { cases: 60, seed: 0xC0DE, ..Default::default() }, &gen, |ids| {
        // Sample period far above the op count: no halving mid-property.
        let mut sketch = FrequencySketch::with_capacity(256);
        let mut truth = std::collections::HashMap::new();
        for &id in ids {
            sketch.increment(BlockId(id));
            *truth.entry(id).or_insert(0u32) += 1;
        }
        for (&id, &count) in &truth {
            let est = sketch.estimate(BlockId(id));
            if est < count.min(15) {
                return Err(format!(
                    "estimate {est} underestimates true count {count} for id {id}"
                ));
            }
        }
        Ok(())
    });
}

/// Halving must age every estimate downward, never upward — the aged
/// estimate still never underestimates the halved true count.
#[test]
fn sketch_halving_is_monotone_and_sound() {
    let gen = VecU64Gen { min_len: 1, max_len: 300, max_value: 32 };
    forall(&Config { cases: 40, seed: 0xA6E, ..Default::default() }, &gen, |ids| {
        let mut sketch = FrequencySketch::with_capacity(128);
        let mut truth = std::collections::HashMap::new();
        for &id in ids {
            sketch.increment(BlockId(id));
            *truth.entry(id).or_insert(0u32) += 1;
        }
        let before: Vec<(u64, u32)> =
            truth.keys().map(|&id| (id, sketch.estimate(BlockId(id)))).collect();
        sketch.halve();
        for (id, est_before) in before {
            let est_after = sketch.estimate(BlockId(id));
            if est_after != est_before / 2 {
                return Err(format!(
                    "halving {est_before} gave {est_after} for id {id}"
                ));
            }
            let count = truth[&id];
            if est_after < (count.min(15)) / 2 {
                return Err(format!(
                    "aged estimate {est_after} underestimates {count}/2 for id {id}"
                ));
            }
        }
        Ok(())
    });
}

/// Doorkeeper soundness: no false negatives while members are live, and a
/// reset leaves no stale membership behind (so a cleared doorkeeper can
/// never inflate a frequency estimate with pre-reset history).
#[test]
fn doorkeeper_reset_preserves_admission_soundness() {
    let gen = VecU64Gen { min_len: 1, max_len: 200, max_value: 10_000 };
    forall(&Config { cases: 60, seed: 0xD00A, ..Default::default() }, &gen, |ids| {
        let mut dk = Doorkeeper::with_capacity(256);
        for &id in ids {
            dk.insert(BlockId(id));
        }
        for &id in ids {
            if !dk.contains(BlockId(id)) {
                return Err(format!("false negative for {id}"));
            }
        }
        dk.clear();
        for &id in ids {
            if dk.contains(BlockId(id)) {
                return Err(format!("stale membership for {id} after reset"));
            }
        }
        Ok(())
    });
}

/// `always` admission must be bit-identical to a cache built without the
/// admission layer, for every replacement policy on every op sequence:
/// same outcomes, same eviction order, same final contents, zero rejects.
#[test]
fn always_admission_is_bit_identical_for_every_policy() {
    let gen = CacheOpsGen { max_ops: 250, keyspace: 40, max_capacity: 12 };
    for &policy in POLICY_NAMES {
        forall(
            &Config { cases: 15, seed: 0xADA + policy.len() as u64, ..Default::default() },
            &gen,
            |(ops, cap)| {
                let mut bare = BlockCache::new(make_policy(policy).unwrap(), *cap);
                let mut gated = CacheBuilder::new()
                    .policy(policy)
                    .admission("always")
                    .capacity(*cap)
                    .build_block_cache()
                    .unwrap();
                for (t, (key, reuse)) in ops.iter().enumerate() {
                    let c = ctx(t as u64, *reuse);
                    let a = bare.access_or_insert(BlockId(*key), &c);
                    let b = gated.access_or_insert(BlockId(*key), &c);
                    if a != b {
                        return Err(format!(
                            "{policy}: divergence at op {t}: {a:?} vs {b:?}"
                        ));
                    }
                }
                if bare.cached_blocks() != gated.cached_blocks() {
                    return Err(format!("{policy}: final contents diverge"));
                }
                if gated.admission_stats().rejected != 0 {
                    return Err(format!("{policy}: always admission rejected something"));
                }
                Ok(())
            },
        );
    }
}

/// The ghost history never exceeds its configured capacity, whatever the
/// mix of probation inserts, admissions and evictions.
#[test]
fn ghost_capacity_invariant_holds() {
    let gen = VecU64Gen { min_len: 1, max_len: 500, max_value: 200 };
    for capacity in [1usize, 3, 16, 64] {
        forall(
            &Config { cases: 30, seed: 0x6057 + capacity as u64, ..Default::default() },
            &gen,
            |ids| {
                let mut g = GhostProbation::new(capacity);
                let mut no_victim = || None::<BlockId>;
                for (i, &id) in ids.iter().enumerate() {
                    // Alternate the two ghost entry points.
                    if i % 3 == 0 {
                        g.on_evict(BlockId(id));
                    } else {
                        let c = ctx(i as u64, false);
                        g.admit(BlockId(id), &c, &mut no_victim);
                    }
                    if g.len() > g.capacity() {
                        return Err(format!(
                            "ghost holds {} of {} after {} ops",
                            g.len(),
                            g.capacity(),
                            i + 1
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}

/// Whatever the (eviction policy, admission policy) pairing, the cache
/// invariants hold: occupancy bounded, accounting exact, counters
/// consistent, admission decisions summing into the stats.
#[test]
fn every_policy_admission_pairing_preserves_invariants() {
    let gen = CacheOpsGen { max_ops: 200, keyspace: 50, max_capacity: 10 };
    for &admission in ADMISSION_NAMES {
        for &policy in ["lru", "h-svm-lru", "wsclock", "modified-arc"].iter() {
            forall(
                &Config {
                    cases: 10,
                    seed: 0xF00 + admission.len() as u64 + policy.len() as u64,
                    ..Default::default()
                },
                &gen,
                |(ops, cap)| {
                    let front = CacheBuilder::new()
                        .policy(policy)
                        .admission(admission)
                        .shards(2)
                        .capacity(*cap)
                        .build()
                        .unwrap();
                    for (t, (key, reuse)) in ops.iter().enumerate() {
                        front.access_or_insert(BlockId(*key), &ctx(t as u64, *reuse));
                        if front.used() > front.capacity() {
                            return Err(format!(
                                "{policy}+{admission}: occupancy {} over {}",
                                front.used(),
                                front.capacity()
                            ));
                        }
                    }
                    let s = front.stats();
                    if s.hits + s.misses != s.requests {
                        return Err(format!("{policy}+{admission}: hits+misses != requests"));
                    }
                    if s.requests != ops.len() as u64 {
                        return Err(format!("{policy}+{admission}: request count off"));
                    }
                    if s.insertions < s.evictions
                        || s.insertions - s.evictions != front.len() as u64
                    {
                        return Err(format!("{policy}+{admission}: conservation broken"));
                    }
                    if s.insertions > s.admitted {
                        return Err(format!(
                            "{policy}+{admission}: {} inserts but only {} admitted",
                            s.insertions, s.admitted
                        ));
                    }
                    if s.admitted + s.rejected > s.misses {
                        return Err(format!(
                            "{policy}+{admission}: more decisions than misses"
                        ));
                    }
                    Ok(())
                },
            );
        }
    }
}

/// Seeded generator reused across the suite — kept here so the admission
/// properties shrink the same way the sharded ones do.
#[test]
fn generators_produce_shrinkable_cases() {
    let gen = CacheOpsGen { max_ops: 20, keyspace: 8, max_capacity: 4 };
    let mut rng = h_svm_lru::util::rng::Pcg64::new(7, 0);
    let case = gen.generate(&mut rng);
    assert!(!gen.shrink(&case).is_empty() || case.0.len() <= 1);
}
