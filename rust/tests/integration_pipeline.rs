//! Full-pipeline integration: cluster provisioning -> MapReduce scheduling
//! -> coordinator (Algorithm 1) -> metrics, across all three §6.4
//! scenarios, using the pure-Rust backend (HLO-path coverage lives in
//! integration_runtime.rs, which needs `make artifacts`).

use h_svm_lru::config::{ClusterConfig, SvmConfig};
use h_svm_lru::coordinator::{CacheCoordinator, CacheMode};
use h_svm_lru::experiments::{run_repeated_job, run_workload, Scenario};
use h_svm_lru::mapreduce::{JobId, Scheduler};
use h_svm_lru::util::bytes::GB;
use h_svm_lru::workload::{instantiate, App, Cluster, WORKLOADS};

fn svm_rust() -> SvmConfig {
    SvmConfig { backend: "rust".into(), ..Default::default() }
}

#[test]
fn workload_pipeline_end_to_end() {
    let cfg = ClusterConfig::default(); // the paper's 9-node testbed
    let run = run_workload(&WORKLOADS[0], &cfg, &Scenario::SvmLru, &svm_rust(), 0.02)
        .expect("W1 under H-SVM-LRU");
    assert_eq!(run.runs.len(), 4);
    for job in &run.runs {
        assert_eq!(job.maps_completed(), job.spec.n_maps());
        assert_eq!(job.reduces_completed(), job.spec.n_reduces);
        assert!(job.finish > job.start);
    }
    assert!(run.hit_ratio > 0.0, "shared inputs must produce hits");
}

#[test]
fn three_scenarios_order_correctly() {
    // H-SVM-LRU <= H-LRU <= H-NoCache on a workload with heavy sharing and
    // pollution (W3: Aggregation + WordCount + Grep + Grep).
    let cfg = ClusterConfig::default();
    let scale = 0.05;
    let nocache = run_workload(&WORKLOADS[2], &cfg, &Scenario::NoCache, &svm_rust(), scale)
        .unwrap()
        .makespan_s;
    let lru = run_workload(
        &WORKLOADS[2],
        &cfg,
        &Scenario::Policy("lru".into()),
        &svm_rust(),
        scale,
    )
    .unwrap()
    .makespan_s;
    let svm = run_workload(&WORKLOADS[2], &cfg, &Scenario::SvmLru, &svm_rust(), scale)
        .unwrap()
        .makespan_s;
    assert!(lru < nocache, "caching must help W3: lru {lru} vs nocache {nocache}");
    assert!(svm < nocache, "svm-lru must help W3: {svm} vs {nocache}");
    assert!(
        svm <= lru * 1.05,
        "svm-lru should not lose to lru on W3: {svm} vs {lru}"
    );
}

#[test]
fn repeated_runs_warm_the_cache() {
    let cfg = ClusterConfig::default();
    let times = run_repeated_job(
        App::WordCount,
        4 * GB,
        &cfg,
        &Scenario::Policy("lru".into()),
        &svm_rust(),
        5,
    )
    .unwrap();
    assert_eq!(times.len(), 5);
    let cold = times[0];
    let warm = times[4];
    assert!(warm < cold, "warm run {warm} should beat cold {cold}");
}

#[test]
fn coordinator_metadata_stays_consistent_under_load() {
    // After a full workload, DataNode ground truth must match NameNode
    // cache metadata exactly (cache reports find nothing to fix).
    let cfg = ClusterConfig::default();
    let mut cluster = Cluster::provision(&cfg);
    let jobs = instantiate(&WORKLOADS[4], &mut cluster, 0.02, 0);
    let mut coord = CacheCoordinator::new(
        cluster,
        CacheMode::Cached { policy: "lru".into() },
        None,
    )
    .unwrap();
    let cfg_ref = coord.cluster.cfg.clone();
    let scheduler = Scheduler::new(&cfg_ref);
    scheduler.run_jobs(&jobs, &mut coord, h_svm_lru::sim::SimTime::ZERO);
    assert!(coord.stats.requests > 0);
    assert_eq!(coord.process_cache_reports(), 0, "metadata drift detected");
    // Occupancy within bounds on every node.
    for dn in &coord.cluster.datanodes {
        assert!(dn.cache_used() <= dn.cache_capacity());
    }
}

#[test]
fn history_feeds_labeling_pipeline() {
    use h_svm_lru::mapreduce::HistoryServer;
    use h_svm_lru::svm::label_record;

    let cfg = ClusterConfig::default();
    let mut cluster = Cluster::provision(&cfg);
    let jobs = instantiate(&WORKLOADS[0], &mut cluster, 0.01, 0);
    let mut coord =
        CacheCoordinator::new(cluster, CacheMode::Cached { policy: "lru".into() }, None)
            .unwrap();
    let cfg_ref = coord.cluster.cfg.clone();
    let scheduler = Scheduler::new(&cfg_ref);
    let runs = scheduler.run_jobs(&jobs, &mut coord, h_svm_lru::sim::SimTime::ZERO);

    let mut history = HistoryServer::new();
    for run in &runs {
        history.ingest(run);
    }
    assert_eq!(history.len(), 7 * runs.len());
    // Table 4 labels apply to every record; both classes appear.
    let labels: Vec<_> = history.records().iter().map(label_record).collect();
    assert!(labels.iter().any(|l| l.map_input_reused || l.reduce_input_reused));
    assert!(labels.iter().any(|l| !l.map_input_reused && !l.reduce_input_reused));
}

#[test]
fn multi_job_fairness() {
    // Two identical jobs sharing the cluster finish within 2x of each
    // other (round-robin slot sharing).
    let cfg = ClusterConfig::default();
    let mut cluster = Cluster::provision(&cfg);
    let fid = cluster.add_input("shared", 2 * GB);
    let blocks: Vec<_> = cluster.namenode.files.blocks_of(fid).to_vec();
    let jobs = vec![
        App::Grep.job(JobId(0), blocks.clone()),
        App::Grep.job(JobId(1), blocks),
    ];
    let mut coord =
        CacheCoordinator::new(cluster, CacheMode::Cached { policy: "lru".into() }, None)
            .unwrap();
    let cfg_ref = coord.cluster.cfg.clone();
    let scheduler = Scheduler::new(&cfg_ref);
    let runs = scheduler.run_jobs(&jobs, &mut coord, h_svm_lru::sim::SimTime::ZERO);
    let t0 = runs[0].execution_time().as_secs_f64();
    let t1 = runs[1].execution_time().as_secs_f64();
    assert!(t0 / t1 < 2.0 && t1 / t0 < 2.0, "unfair: {t0} vs {t1}");
}

#[test]
fn shipped_config_file_loads() {
    let (cluster, svm) = h_svm_lru::config::load(Some("configs/cluster.toml")).unwrap();
    assert_eq!(cluster.datanodes, 9);
    assert_eq!(cluster.cache_blocks_per_node(), 12);
    assert!(!cluster.speculative_execution);
    assert_eq!(svm.kernel, "rbf");
}

#[test]
fn prefetching_improves_repeat_scans() {
    // Same Poisson scenario with and without the SVM-gated prefetcher:
    // sequential scans should hit more with it on (ablation 3's claim).
    use h_svm_lru::experiments::simulate::{self, SimulateConfig};
    let cfg = ClusterConfig { datanodes: 3, replication: 2, ..Default::default() };
    let base = SimulateConfig { n_jobs: 12, seed: 21, ..Default::default() };
    let off = simulate::run(&cfg, &Scenario::SvmLru, &svm_rust(), &base).unwrap();
    let on = simulate::run(
        &cfg,
        &Scenario::SvmLru,
        &svm_rust(),
        &SimulateConfig { prefetch_depth: 2, ..base },
    )
    .unwrap();
    assert!(
        on.hit_ratio >= off.hit_ratio,
        "prefetch should not hurt: {} vs {}",
        on.hit_ratio,
        off.hit_ratio
    );
}

#[test]
fn failure_injection_keeps_metadata_consistent() {
    use h_svm_lru::experiments::simulate::{self, SimulateConfig};
    use h_svm_lru::mapreduce::FailureModel;
    let cfg = ClusterConfig { datanodes: 3, replication: 2, ..Default::default() };
    let sim = SimulateConfig {
        n_jobs: 10,
        failures: FailureModel::with_rates(0.2, 0.05, 3),
        ..Default::default()
    };
    let report = simulate::run(&cfg, &Scenario::Policy("lru".into()), &svm_rust(), &sim).unwrap();
    assert_eq!(report.completed.len(), 10);
    assert!(report.failed_attempts + report.killed_attempts > 0);
    assert_eq!(report.metadata_fixes, 0, "heartbeat reconciliation found drift");
}
