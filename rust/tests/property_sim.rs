//! Property tests over the DES core and the cluster substrate.

use h_svm_lru::config::ClusterConfig;
use h_svm_lru::hdfs::{DataNode, DataNodeId, NameNode, Placement};
use h_svm_lru::sim::{Engine, Resource, SimDuration, SimTime};
use h_svm_lru::testkit::{forall, Config, Gen, VecU64Gen};
use h_svm_lru::util::bytes::MB;
use h_svm_lru::util::rng::Pcg64;

#[test]
fn engine_time_never_goes_backwards() {
    let gen = VecU64Gen { min_len: 1, max_len: 200, max_value: 10_000 };
    forall(&Config { cases: 50, ..Default::default() }, &gen, |delays| {
        let mut eng: Engine<Vec<u64>> = Engine::new();
        for &d in delays {
            eng.schedule_at(SimTime(d), move |eng, log: &mut Vec<u64>| {
                log.push(eng.now().micros());
            });
        }
        let mut log = Vec::new();
        eng.run(&mut log);
        if log.len() != delays.len() {
            return Err("event lost".into());
        }
        for w in log.windows(2) {
            if w[0] > w[1] {
                return Err(format!("time travel: {} then {}", w[0], w[1]));
            }
        }
        Ok(())
    });
}

#[test]
fn engine_fires_exactly_once_per_event() {
    let gen = VecU64Gen { min_len: 0, max_len: 300, max_value: 1000 };
    forall(&Config { cases: 40, ..Default::default() }, &gen, |delays| {
        let mut eng: Engine<u64> = Engine::new();
        for &d in delays {
            eng.schedule_at(SimTime(d), |_, count: &mut u64| *count += 1);
        }
        let mut count = 0u64;
        eng.run(&mut count);
        if count != delays.len() as u64 {
            return Err(format!("{count} fires for {} events", delays.len()));
        }
        if eng.pending() != 0 {
            return Err("queue not drained".into());
        }
        Ok(())
    });
}

#[test]
fn resource_serves_fifo_without_overlap() {
    // On a single server, grants must be non-overlapping and ordered.
    let gen = VecU64Gen { min_len: 1, max_len: 100, max_value: 500 };
    forall(&Config { cases: 50, ..Default::default() }, &gen, |services| {
        let mut disk = Resource::new("disk", 1);
        let mut last_end = SimTime::ZERO;
        let mut busy_sum = 0u64;
        for (i, &svc) in services.iter().enumerate() {
            let now = SimTime(i as u64); // requests arrive in time order
            let (start, end) = disk.acquire(now, SimDuration(svc));
            if start < now {
                return Err("service started before request".into());
            }
            if start < last_end {
                return Err("overlapping grants on a single server".into());
            }
            if (end - start) != SimDuration(svc) {
                return Err("service time not honored".into());
            }
            last_end = end;
            busy_sum += svc;
        }
        if disk.busy_time() != SimDuration(busy_sum) {
            return Err("busy accounting broken".into());
        }
        Ok(())
    });
}

#[test]
fn multi_server_capacity_is_respected() {
    // With c servers and all requests at t=0, max concurrency == c and
    // total completion time >= sum/c.
    let gen = VecU64Gen { min_len: 1, max_len: 64, max_value: 200 };
    forall(&Config { cases: 40, ..Default::default() }, &gen, |services| {
        for servers in [1usize, 2, 4] {
            let mut cpu = Resource::new("cpu", servers);
            let mut intervals = Vec::new();
            for &svc in services {
                let (s, e) = cpu.acquire(SimTime::ZERO, SimDuration(svc + 1));
                intervals.push((s.micros(), e.micros()));
            }
            // Check concurrency at every start point.
            for &(t, _) in &intervals {
                let overlapping = intervals
                    .iter()
                    .filter(|&&(s, e)| s <= t && t < e)
                    .count();
                if overlapping > servers {
                    return Err(format!(
                        "{overlapping} concurrent services on {servers} servers"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Generator for cluster shapes.
struct ClusterGen;

impl Gen for ClusterGen {
    type Value = (usize, usize, u64);

    fn generate(&self, rng: &mut Pcg64) -> Self::Value {
        let nodes = 1 + rng.gen_range(12) as usize;
        let repl = 1 + rng.gen_range((nodes as u64).min(4)) as usize;
        let blocks = 1 + rng.gen_range(100);
        (nodes, repl, blocks)
    }
}

#[test]
fn replica_placement_invariants() {
    forall(&Config { cases: 60, ..Default::default() }, &ClusterGen, |&(nodes, repl, blocks)| {
        let mut p = Placement::new(nodes, repl, Pcg64::new(1, 2));
        for _ in 0..blocks {
            let chosen = p.place();
            if chosen.len() != repl {
                return Err("wrong replica count".into());
            }
            let mut uniq: Vec<_> = chosen.clone();
            uniq.sort();
            uniq.dedup();
            if uniq.len() != repl {
                return Err("duplicate replica nodes".into());
            }
        }
        let load = p.per_node_load();
        let min = load.iter().min().unwrap();
        let max = load.iter().max().unwrap();
        if max - min > 1 {
            return Err(format!("unbalanced placement: {load:?}"));
        }
        Ok(())
    });
}

#[test]
fn namenode_cache_report_reconciliation_is_idempotent() {
    let gen = VecU64Gen { min_len: 1, max_len: 40, max_value: 40 };
    forall(&Config { cases: 40, ..Default::default() }, &gen, |cached_ids| {
        let cfg = ClusterConfig {
            datanodes: 3,
            replication: 1,
            block_size: 64 * MB,
            ..Default::default()
        };
        let mut nn = NameNode::new(3, 1, Pcg64::new(9, 9));
        let mut dns: Vec<DataNode> = (0..3)
            .map(|i| DataNode::new(DataNodeId(i), cfg.cache_capacity_per_node))
            .collect();
        nn.register_file("f", 40 * 64 * MB, 64 * MB, h_svm_lru::hdfs::BlockKind::Input, &mut dns);
        // Cache some blocks on their replica nodes (ground truth).
        for &id in cached_ids {
            let b = h_svm_lru::hdfs::BlockId(id % 40);
            if let Some(&dn) = nn.replicas_of(b).first() {
                dns[dn.0 as usize].cache_block(b, 64 * MB);
            }
        }
        // Reports reconcile metadata; a second pass must be a no-op.
        let mut first = 0;
        for dn in &dns {
            first += nn.apply_cache_report(dn.id, &dn.cache_report());
        }
        let mut second = 0;
        for dn in &dns {
            second += nn.apply_cache_report(dn.id, &dn.cache_report());
        }
        if second != 0 {
            return Err(format!("reconciliation not idempotent: {first} then {second}"));
        }
        Ok(())
    });
}
