//! Property tests for the lock-free hit path (`cache::read_path` +
//! `ReadHandle`): merged `ShardStats` hit/miss totals are *exact* — not
//! approximate — under buffered recency, because a buffered hit counts at
//! read time, not drain time. Batched replays are bit-identical to the
//! immediate (batch 1) baseline at 1 and 8 shards under the same seed,
//! mid-run snapshots agree while accesses are still buffered, and the
//! same guarantee holds under the scripted chaos plans of the
//! fault-injection layer (rust/tests/property_faults.rs).

use h_svm_lru::cache::sharded::{shard_of, ShardStats, ShardedCache};
use h_svm_lru::cache::{AccessContext, CacheBuilder, RecencyConfig};
use h_svm_lru::experiments::chaos::{
    breaker_for_trace, default_serving_plan, run_serving_chaos,
};
use h_svm_lru::hdfs::BlockId;
use h_svm_lru::obs::{MetricsRegistry, DEFAULT_WINDOW_US};
use h_svm_lru::sim::parallel::{run_fanout, FanoutOptions};
use h_svm_lru::sim::{FaultInjector, SimDuration, SimTime};
use h_svm_lru::svm::KernelKind;
use h_svm_lru::testkit::{forall, CacheOpsGen, Config};
use h_svm_lru::util::bytes::MB;
use h_svm_lru::workload::fig3_trace;

fn ctx(t: u64, reuse: bool) -> AccessContext {
    AccessContext::simple(SimTime(t), 1).with_prediction(reuse)
}

fn cache(policy: &str, shards: usize, capacity: u64, recency: RecencyConfig) -> ShardedCache {
    CacheBuilder::new()
        .policy(policy)
        .shards(shards)
        .capacity(capacity)
        .recency(recency)
        .build()
        .unwrap_or_else(|e| panic!("{policy} cache: {e}"))
}

/// Replay `ops` with one `ReadHandle`-driving worker per shard (the
/// replay topology: each shard touched by exactly one handle) and return
/// the whole observable surface: per-op hit verdicts per worker, merged
/// stats, per-shard stats, final contents and occupancy.
fn fanout_replay(
    policy: &str,
    shards: usize,
    capacity: u64,
    recency: RecencyConfig,
    ops: &[(u64, bool)],
) -> (Vec<Vec<bool>>, ShardStats, Vec<ShardStats>, Vec<BlockId>, u64) {
    let c = cache(policy, shards, capacity, recency);
    let worker = |w: usize| {
        let mut handle = c.read_handle();
        let mut hits = Vec::new();
        for (t, (key, reuse)) in ops.iter().enumerate() {
            let b = BlockId(*key);
            if shard_of(b, shards) == w {
                hits.push(handle.access_or_insert(b, &ctx(t as u64, *reuse)).hit);
            }
        }
        hits
    };
    let per_worker = run_fanout(shards, worker, FanoutOptions::new()).into_workers();
    let mut blocks = c.cached_blocks();
    blocks.sort_unstable();
    (per_worker, c.stats(), c.shard_stats(), blocks, c.used())
}

/// The headline equivalence: with one handle per shard, a batched replay
/// — any batch size, with or without a drain cadence — is bit-identical
/// to the immediate (batch 1) baseline: same per-op hit verdicts, same
/// merged and per-shard stats, same final contents. At 1 and 8 shards,
/// for both a plain and a classifier-driven policy.
#[test]
fn batched_fanout_replay_is_bit_identical_to_immediate() {
    let gen = CacheOpsGen { max_ops: 300, keyspace: 40, max_capacity: 12 };
    let variants = [
        RecencyConfig::default().with_batch(8),
        RecencyConfig::default().with_batch(256),
        RecencyConfig::default()
            .with_batch(256)
            .with_drain_cadence(SimDuration::from_micros(3)),
    ];
    for &policy in &["lru", "h-svm-lru"] {
        for shards in [1usize, 8] {
            forall(
                &Config {
                    cases: 10,
                    seed: 0x5EA0 + shards as u64 + policy.len() as u64,
                    ..Default::default()
                },
                &gen,
                |(ops, cap)| {
                    let baseline =
                        fanout_replay(policy, shards, *cap, RecencyConfig::default(), ops);
                    for recency in variants {
                        let batched = fanout_replay(policy, shards, *cap, recency, ops);
                        if batched != baseline {
                            return Err(format!(
                                "{policy}/{shards} shard(s): batch {} diverged from immediate",
                                recency.batch
                            ));
                        }
                    }
                    let stats = &baseline.1;
                    if stats.hits + stats.misses != stats.requests {
                        return Err("hits + misses != requests".into());
                    }
                    if stats.requests != ops.len() as u64 {
                        return Err("request count off".into());
                    }
                    Ok(())
                },
            );
        }
    }
}

/// Exactness mid-run: a buffered hit counts at read time, so *every*
/// prefix of a batched replay reports the same merged totals as the
/// immediate twin — even while `pending() > 0` — and the ledger
/// `hits + misses == requests` never goes transiently stale.
#[test]
fn buffered_hits_count_at_read_time_in_every_snapshot() {
    let gen = CacheOpsGen { max_ops: 200, keyspace: 24, max_capacity: 10 };
    let mut saw_pending = false;
    forall(&Config { cases: 20, seed: 0xBEAD, ..Default::default() }, &gen, |(ops, cap)| {
        let immediate = cache("lru", 2, *cap, RecencyConfig::default());
        let batched = cache("lru", 2, *cap, RecencyConfig::default().with_batch(64));
        let mut im = immediate.read_handle();
        let mut ba = batched.read_handle();
        for (t, (key, reuse)) in ops.iter().enumerate() {
            let c = ctx(t as u64, *reuse);
            let a = im.access_or_insert(BlockId(*key), &c);
            let b = ba.access_or_insert(BlockId(*key), &c);
            if a != b {
                return Err(format!("op {t}: outcome diverged: {a:?} vs {b:?}"));
            }
            saw_pending |= ba.pending() > 0;
            let (si, sb) = (immediate.stats(), batched.stats());
            if si != sb {
                return Err(format!(
                    "op {t}: snapshot diverged with {} pending: {si:?} vs {sb:?}",
                    ba.pending()
                ));
            }
            if sb.hits + sb.misses != sb.requests || sb.requests != t as u64 + 1 {
                return Err(format!("op {t}: ledger not exact: {sb:?}"));
            }
        }
        Ok(())
    });
    // The property is vacuous unless some snapshot was taken while
    // accesses were still buffered — with 20 cases of repeat-heavy
    // streams at batch 64, at least one lock-free hit must have buffered.
    assert!(saw_pending, "no snapshot ever observed a buffered hit");
}

/// The chaos leg: under the scripted serving plan (classifier outage +
/// latency spike), same seed and breaker, a buffered-recency replay
/// reports the exact same merged stats, windowed series and breaker
/// counters as the immediate one — at 1 and 8 shards. Recency batching
/// touches only the cache's recency bookkeeping; hit/miss accounting and
/// the classifier path are bit-identical.
#[test]
fn chaos_replay_under_buffered_recency_is_bit_identical() {
    let trace = fig3_trace(64 * MB, 11);
    let run = |shards: usize, recency: RecencyConfig| {
        let injector = FaultInjector::new(default_serving_plan(&trace, 11));
        run_serving_chaos(
            "h-svm-lru",
            shards,
            8 * 64 * MB,
            &trace,
            KernelKind::Rbf,
            breaker_for_trace(&trace),
            &injector,
            &MetricsRegistry::disabled(),
            DEFAULT_WINDOW_US,
            recency,
        )
        .expect("chaos replay")
    };
    for shards in [1usize, 8] {
        let baseline = run(shards, RecencyConfig::default());
        assert_eq!(
            baseline.stats.hits + baseline.stats.misses,
            baseline.stats.requests,
            "chaos ledger must stay exact"
        );
        assert_eq!(baseline.stats.requests, trace.len() as u64);
        for recency in [
            RecencyConfig::default().with_batch(16),
            RecencyConfig::default()
                .with_batch(256)
                .with_drain_cadence(SimDuration::from_micros(50_000)),
        ] {
            let under = run(shards, recency);
            assert_eq!(
                under.stats, baseline.stats,
                "batch {} chaos stats diverged at {shards} shard(s)",
                recency.batch
            );
            assert_eq!(under.windows, baseline.windows, "windowed series diverged");
            assert_eq!(under.breaker_opens, baseline.breaker_opens);
            assert_eq!(under.breaker_closes, baseline.breaker_closes);
            assert_eq!(under.breaker_fallbacks, baseline.breaker_fallbacks);
            assert_eq!(under.backend_failures, baseline.backend_failures);
        }
    }
}
