//! Experiments smoke + paper-shape checks: every table/figure driver runs
//! at full fidelity with the rust backend (they are fast by construction)
//! and reproduces the qualitative claims of the paper's evaluation.

use h_svm_lru::config::SvmConfig;
use h_svm_lru::experiments::{fig3, fig4, fig5, fig6, table5, table7};
use h_svm_lru::svm::KernelKind;
use h_svm_lru::util::bytes::MB;

fn svm_rust() -> SvmConfig {
    SvmConfig { backend: "rust".into(), ..Default::default() }
}

const SEED: u64 = 20230101;

#[test]
fn fig3_svm_lru_dominates_lru() {
    let points = fig3::run(&svm_rust(), SEED).expect("fig3");
    assert_eq!(points.len(), 14, "10 sizes @64MB + 4 @128MB");
    for p in &points {
        assert!(
            p.svm_lru >= p.lru - 1e-9,
            "cache {} blocks {}: svm {} < lru {}",
            p.cache_blocks,
            p.block_size,
            p.svm_lru,
            p.lru
        );
    }
    // Hit ratio grows with cache size for both policies (paper Fig 3).
    for bs in [64 * MB, 128 * MB] {
        let series: Vec<&fig3::HitRatioPoint> =
            points.iter().filter(|p| p.block_size == bs).collect();
        for w in series.windows(2) {
            assert!(
                w[1].lru >= w[0].lru - 0.02,
                "LRU hit ratio should grow with cache size"
            );
            assert!(
                w[1].svm_lru >= w[0].svm_lru - 0.02,
                "H-SVM-LRU hit ratio should grow with cache size"
            );
        }
    }
    // Bigger blocks -> higher hit ratio at the same block count (paper).
    let hr64 = points.iter().find(|p| p.block_size == 64 * MB && p.cache_blocks == 6).unwrap();
    let hr128 = points.iter().find(|p| p.block_size == 128 * MB && p.cache_blocks == 6).unwrap();
    assert!(hr128.lru > hr64.lru);
}

#[test]
fn table7_improvement_largest_at_small_cache() {
    let points = table7::run(&svm_rust(), SEED).expect("table7");
    let ir = |blocks: u64, bs: u64| {
        points
            .iter()
            .find(|p| p.cache_blocks == blocks && p.block_size == bs)
            .map(|p| p.improvement_ratio())
            .unwrap()
    };
    assert!(ir(6, 64 * MB) > ir(24, 64 * MB), "IR must shrink with cache size");
    assert!(ir(6, 64 * MB) > ir(6, 128 * MB), "IR larger for small blocks (paper)");
    assert!(ir(6, 64 * MB) > 0.10, "small-cache IR should be substantial");
}

#[test]
fn fig4_cached_never_loses_and_svm_wins_beyond_capacity() {
    let points = fig4::run(&svm_rust(), SEED).expect("fig4");
    for p in &points {
        assert!(p.lru_s <= p.nocache_s * 1.02, "H-LRU lost to NoCache at {:?}", p);
        assert!(p.svm_lru_s <= p.nocache_s * 1.02, "H-SVM-LRU lost to NoCache at {:?}", p);
    }
    // Beyond the 13.5 GB aggregate cache, LRU thrashes but SVM-LRU holds.
    let big: Vec<_> = points.iter().filter(|p| p.input_bytes >= 16 * 1024 * MB).collect();
    assert!(!big.is_empty());
    for p in big {
        assert!(
            p.svm_lru_s <= p.lru_s * 1.02,
            "SVM-LRU should dominate LRU beyond capacity: {:?}",
            p
        );
    }
}

#[test]
fn fig5_headline_improvements() {
    let points = fig5::run(&svm_rust(), SEED, fig5::DEFAULT_SCALE).expect("fig5");
    assert_eq!(points.len(), 6);
    let (lru_impr, svm_impr, over) = fig5::summary(&points);
    // Paper: 11.33% / 16.16% / 4.83%. Shapes, not absolutes:
    assert!(lru_impr > 0.0, "H-LRU must improve over NoCache ({lru_impr:.2}%)");
    assert!(svm_impr > lru_impr - 0.5, "H-SVM-LRU must not lose to H-LRU ({svm_impr:.2}% vs {lru_impr:.2}%)");
    assert!(over > 0.0, "H-SVM-LRU should beat H-LRU on average ({over:.2}%)");
    // W3 is among the best improvements for H-SVM-LRU (paper: W3 & W5).
    let mut by_norm: Vec<&fig5::WorkloadPoint> = points.iter().collect();
    by_norm.sort_by(|a, b| a.svm_lru_norm.partial_cmp(&b.svm_lru_norm).unwrap());
    let top2: Vec<&str> = by_norm[..2].iter().map(|p| p.name).collect();
    assert!(top2.contains(&"W3"), "W3 should be a top improver, got {top2:?}");
}

#[test]
fn fig6_join_benefits_least() {
    let points = fig6::run(&svm_rust(), SEED, fig5::DEFAULT_SCALE).expect("fig6");
    assert_eq!(points.len(), 6);
    let means = fig6::per_app_means(&points);
    let get = |n: &str| means.iter().find(|(a, _)| a == n).map(|(_, m)| *m).unwrap();
    // Paper §6.4.2: multi-stage Join has difficulty reusing inputs.
    assert!(get("Join") >= get("Grep"), "Join should benefit less than Grep");
    assert!(get("Join") >= get("Aggregation"), "Join should benefit least of hive apps");
    // Everything still improves or stays flat vs NoCache.
    for (app, m) in &means {
        assert!(*m < 1.1, "{app} regressed: {m}");
    }
}

#[test]
fn table5_rbf_wins_sigmoid_collapses() {
    let evals = table5::run(&svm_rust(), SEED).expect("table5");
    assert_eq!(evals.len(), 3);
    let acc = |k: KernelKind| evals.iter().find(|e| e.kernel == k).unwrap().test_accuracy;
    assert!(acc(KernelKind::Rbf) >= acc(KernelKind::Sigmoid), "RBF must beat sigmoid");
    assert!(acc(KernelKind::Rbf) > 0.7, "RBF accuracy too low");
    // Confusion matrices are complete (all test rows accounted for).
    for e in &evals {
        assert!(e.cm.total() > 50, "{:?}: too few test rows", e.kernel);
    }
}

#[test]
fn cross_validation_accuracy_in_paper_band() {
    let acc = table5::cross_validated_accuracy(&svm_rust(), SEED, 4).expect("cv");
    // Paper reports 83%; accept a generous band around it.
    assert!(acc > 0.7 && acc <= 1.0, "CV accuracy {acc} far from paper's 0.83");
}

#[test]
fn experiments_are_deterministic_for_a_seed() {
    let a = fig3::run(&svm_rust(), 777).expect("fig3 a");
    let b = fig3::run(&svm_rust(), 777).expect("fig3 b");
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.lru, y.lru);
        assert_eq!(x.svm_lru, y.svm_lru);
    }
}
