//! Repo-invariant lint: a source-walking test that keeps the crate's
//! concurrency and determinism rules true *by construction*, not by
//! review. It fails the build if:
//!
//! 1. `std::sync::atomic` / `core::sync::atomic` is imported anywhere in
//!    `src/` outside the vetted facade modules (everything else must go
//!    through `crate::util::sync`, so loom can swap the primitives under
//!    `--cfg loom`);
//! 2. `std::thread` is used in `src/` outside the modules vetted for
//!    scoped parallelism;
//! 3. wall-clock types (`std::time::Instant` / `std::time::SystemTime`)
//!    appear in `src/` outside the modules allowed to log `Volatile`
//!    (report-only, never exported) metrics — the deterministic replay
//!    core must tell time only via `sim::SimTime`;
//! 4. the token `unsafe` appears anywhere in `src/`, `tests/`, `benches/`
//!    or `examples/` — belt to the crate-level `#![forbid(unsafe_code)]`
//!    suspenders, extended to targets the crate attribute does not cover.
//!
//! Comments and string/char literals are stripped before matching, so
//! prose *about* these constructs (like this header) never trips the
//! lint. The `planted_*` tests below prove each rule actually fires by
//! scanning a temp tree seeded with a violation; `repo_is_clean` proves
//! the real tree passes. clippy.toml's `disallowed-methods` enforces the
//! wall-clock rule at call sites too (with `#[allow]` at the vetted
//! ones); this test is the half that works without clippy in the loop.

use std::fmt::Write as _;
use std::fs;
use std::path::{Path, PathBuf};

/// One lint rule: forbidden tokens plus the files vetted to contain them.
struct Rule {
    name: &'static str,
    /// Substrings that constitute a violation in stripped source.
    /// Matched with identifier boundaries on both ends.
    tokens: &'static [&'static str],
    /// Directories under the crate root to scan.
    roots: &'static [&'static str],
    /// Files (paths relative to the crate root, `/`-separated) where the
    /// tokens are allowed. Each entry carries its justification here, in
    /// the one place the allow-list is defined.
    allowed: &'static [&'static str],
}

/// Rule 1 — raw atomics only inside the facade.
const ATOMICS: Rule = Rule {
    name: "raw-atomics-outside-facade",
    tokens: &["std::sync::atomic", "core::sync::atomic"],
    roots: &["src"],
    allowed: &[
        // The facade itself: the one place that names std's atomics (and
        // loom's, under `--cfg loom`).
        "src/util/sync.rs",
        // The logger's `static MAX_LEVEL: AtomicU8` needs const
        // construction, which loom's types don't offer; it is
        // intentionally outside the modeled protocols.
        "src/util/logger.rs",
    ],
};

/// Rule 2 — `std::thread` only in the vetted scoped-parallelism modules.
const THREADS: Rule = Rule {
    name: "std-thread-outside-vetted-modules",
    tokens: &["std::thread"],
    roots: &["src"],
    allowed: &[
        // The scoped fan-out helpers every parallel driver goes through.
        "src/sim/parallel.rs",
        // Shard replay spawns its monitor/driver threads directly.
        "src/experiments/sharded_replay.rs",
        // The sharded cache front's own scoped workers.
        "src/cache/sharded.rs",
        // `#[cfg(all(test, not(loom)))]` stress tests on real threads;
        // the loom models in tests/loom_protocols.rs cover the same
        // protocols exhaustively.
        "src/cache/shard_stats.rs",
        "src/obs/histogram.rs",
        // The read-view membership table: same pattern — a real-thread
        // churn/rebuild stress test next to the loom model (protocol 5).
        "src/cache/read_path.rs",
    ],
};

/// Rule 3 — wall clocks only where `MetricClass::Volatile` data is born.
const WALL_CLOCK: Rule = Rule {
    name: "wall-clock-outside-volatile-reporting",
    tokens: &[
        "std::time::Instant",
        "std::time::SystemTime",
        "Instant::now",
        "SystemTime::now",
    ],
    roots: &["src"],
    allowed: &[
        // Flush-latency observation (`flush_now`): logged, never exported.
        "src/coordinator/batcher.rs",
        // Replay wall time + throughput reporting (Volatile class).
        "src/experiments/sharded_replay.rs",
        "src/experiments/online_sharded.rs",
        // The bench harness: timing is its whole job; bench output is
        // never part of the deterministic export.
        "src/bench_support/mod.rs",
    ],
};

/// Rule 4 — no `unsafe`, anywhere, including targets that the crate-level
/// `#![forbid(unsafe_code)]` in src/lib.rs does not govern.
const UNSAFE: Rule = Rule {
    name: "unsafe-anywhere",
    tokens: &["unsafe"],
    roots: &["src", "tests", "benches", "examples"],
    allowed: &[],
};

const RULES: &[&Rule] = &[&ATOMICS, &THREADS, &WALL_CLOCK, &UNSAFE];

/// Replace comments and string/char literals with spaces (newlines kept,
/// so reported line numbers stay true). Handles line + nested block
/// comments, escapes in `"…"` strings, raw strings `r#"…"#` (any number
/// of hashes), and char literals — including `'"'`, which would otherwise
/// open a phantom string — while leaving lifetimes (`'a`) alone.
fn strip_comments_and_strings(src: &str) -> String {
    let b: Vec<char> = src.chars().collect();
    let mut out = String::with_capacity(src.len());
    let mut i = 0;
    let blank = |c: char| if c == '\n' { '\n' } else { ' ' };
    while i < b.len() {
        let c = b[i];
        // Line comment.
        if c == '/' && b.get(i + 1) == Some(&'/') {
            while i < b.len() && b[i] != '\n' {
                out.push(' ');
                i += 1;
            }
            continue;
        }
        // Block comment (Rust block comments nest).
        if c == '/' && b.get(i + 1) == Some(&'*') {
            let mut depth = 0usize;
            while i < b.len() {
                if b[i] == '/' && b.get(i + 1) == Some(&'*') {
                    depth += 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                } else if b[i] == '*' && b.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    out.push(blank(b[i]));
                    i += 1;
                }
            }
            continue;
        }
        // Raw string: r"…" or r#"…"# with any number of hashes.
        if c == 'r' && matches!(b.get(i + 1), Some(&'"') | Some(&'#')) {
            let mut j = i + 1;
            let mut hashes = 0usize;
            while b.get(j) == Some(&'#') {
                hashes += 1;
                j += 1;
            }
            if b.get(j) == Some(&'"') {
                out.push(' '); // the `r`
                for _ in 0..hashes {
                    out.push(' ');
                }
                out.push(' '); // opening quote
                j += 1;
                'raw: while j < b.len() {
                    if b[j] == '"' {
                        let mut k = 0usize;
                        while k < hashes && b.get(j + 1 + k) == Some(&'#') {
                            k += 1;
                        }
                        if k == hashes {
                            for _ in 0..=hashes {
                                out.push(' ');
                            }
                            j += 1 + hashes;
                            break 'raw;
                        }
                    }
                    out.push(blank(b[j]));
                    j += 1;
                }
                i = j;
                continue;
            }
            // `r` not starting a raw string (e.g. `r#keyword`): fall through.
        }
        // Ordinary string literal.
        if c == '"' {
            out.push(' ');
            i += 1;
            while i < b.len() {
                if b[i] == '\\' {
                    out.push(' ');
                    out.push(' ');
                    i += 2;
                    continue;
                }
                if b[i] == '"' {
                    out.push(' ');
                    i += 1;
                    break;
                }
                out.push(blank(b[i]));
                i += 1;
            }
            continue;
        }
        // Char literal vs lifetime: `'x'` / `'\n'` are literals (blank
        // them — a `'"'` must not open a string); `'label` is a lifetime.
        if c == '\'' {
            if b.get(i + 1) == Some(&'\\') {
                // Escaped char literal: skip to the closing quote.
                let mut j = i + 2;
                while j < b.len() && b[j] != '\'' {
                    j += 1;
                }
                for _ in i..=j.min(b.len() - 1) {
                    out.push(' ');
                }
                i = j + 1;
                continue;
            }
            if b.get(i + 2) == Some(&'\'') {
                out.push(' ');
                out.push(' ');
                out.push(' ');
                i += 3;
                continue;
            }
            // Lifetime — emit verbatim.
            out.push(c);
            i += 1;
            continue;
        }
        out.push(c);
        i += 1;
    }
    out
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Find rule violations in one file's (already stripped) source.
fn violations_in(stripped: &str, rule: &Rule) -> Vec<(usize, &'static str)> {
    let mut out = Vec::new();
    for (lineno, line) in stripped.lines().enumerate() {
        for &tok in rule.tokens {
            let mut start = 0;
            while let Some(pos) = line[start..].find(tok) {
                let at = start + pos;
                let before_ok = at == 0
                    || !is_ident_char(line[..at].chars().next_back().unwrap());
                let after_ok = line[at + tok.len()..]
                    .chars()
                    .next()
                    .map_or(true, |c| !is_ident_char(c));
                if before_ok && after_ok {
                    out.push((lineno + 1, tok));
                }
                start = at + tok.len();
            }
        }
    }
    out
}

fn walk(dir: &Path, files: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            walk(&path, files);
        } else if path.extension().is_some_and(|e| e == "rs") {
            files.push(path);
        }
    }
    files.sort();
}

/// Scan a crate tree rooted at `root` with `rule`; return formatted
/// violation records (`path:line token`).
fn scan(root: &Path, rule: &Rule) -> Vec<String> {
    let mut found = Vec::new();
    for sub in rule.roots {
        let mut files = Vec::new();
        walk(&root.join(sub), &mut files);
        for file in files {
            let rel = file
                .strip_prefix(root)
                .unwrap_or(&file)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            if rule.allowed.contains(&rel.as_str()) {
                continue;
            }
            let src = fs::read_to_string(&file)
                .unwrap_or_else(|e| panic!("read {}: {e}", file.display()));
            let stripped = strip_comments_and_strings(&src);
            for (line, tok) in violations_in(&stripped, rule) {
                found.push(format!("{rel}:{line} `{tok}`"));
            }
        }
    }
    found
}

fn crate_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// The positive check: the real tree is clean under every rule.
#[test]
fn repo_is_clean() {
    let root = crate_root();
    let mut report = String::new();
    for rule in RULES {
        for v in scan(&root, rule) {
            writeln!(report, "[{}] {v}", rule.name).unwrap();
        }
    }
    assert!(
        report.is_empty(),
        "repo-invariant lint violations (route atomics/threads through \
         crate::util::sync / sim::parallel, keep wall clocks in Volatile \
         reporting modules, or extend the allow-list in \
         rust/tests/lint_invariants.rs with a justification):\n{report}"
    );
}

// ---------------------------------------------------------------------
// Negative self-tests: plant one violation per rule in a temp tree and
// prove the walker catches it — a lint that cannot fail protects nothing.
// ---------------------------------------------------------------------

/// Build a throwaway crate tree containing `planted` at `rel_path`,
/// run `rule` over it, and return the violations.
fn scan_planted(tag: &str, rel_path: &str, planted: &str, rule: &Rule) -> Vec<String> {
    let root = std::env::temp_dir().join(format!(
        "hsvmlru_lint_selftest_{}_{tag}",
        std::process::id()
    ));
    let file = root.join(rel_path);
    fs::create_dir_all(file.parent().unwrap()).unwrap();
    fs::write(&file, planted).unwrap();
    let found = scan(&root, rule);
    fs::remove_dir_all(&root).ok();
    found
}

#[test]
fn planted_atomics_import_is_caught() {
    let found = scan_planted(
        "atomics",
        "src/cache/rogue.rs",
        "use std::sync::atomic::AtomicU64;\n",
        &ATOMICS,
    );
    assert_eq!(found, ["src/cache/rogue.rs:1 `std::sync::atomic`"]);
}

#[test]
fn planted_thread_use_is_caught() {
    let found = scan_planted(
        "thread",
        "src/svm/rogue.rs",
        "pub fn go() { std::thread::spawn(|| {}); }\n",
        &THREADS,
    );
    assert_eq!(found, ["src/svm/rogue.rs:1 `std::thread`"]);
}

#[test]
fn planted_wall_clock_is_caught() {
    let found = scan_planted(
        "clock",
        "src/sim/rogue.rs",
        "use std::time::Instant;\npub fn t() -> Instant { Instant::now() }\n",
        &WALL_CLOCK,
    );
    assert_eq!(
        found,
        [
            "src/sim/rogue.rs:1 `std::time::Instant`",
            "src/sim/rogue.rs:2 `Instant::now`"
        ]
    );
}

#[test]
fn planted_unsafe_is_caught_even_in_tests_dir() {
    let found = scan_planted(
        "unsafe",
        "tests/rogue.rs",
        "fn f(p: *const u8) -> u8 { unsafe { *p } }\n",
        &UNSAFE,
    );
    assert_eq!(found, ["tests/rogue.rs:1 `unsafe`"]);
}

#[test]
fn allow_list_suppresses_only_the_vetted_file() {
    // The same content is a violation at a rogue path…
    let content = "use std::sync::atomic::AtomicU64;\n";
    assert!(!scan_planted("allowed_a", "src/obs/rogue.rs", content, &ATOMICS).is_empty());
    // …and clean at the facade path.
    assert!(scan_planted("allowed_b", "src/util/sync.rs", content, &ATOMICS).is_empty());
}

#[test]
fn read_path_thread_exemption_is_scoped_to_that_file() {
    // The read-view stress test's `std::thread::scope` is vetted at its
    // own path only — a sibling module cannot ride on the entry.
    let content = "fn stress() { std::thread::scope(|_| {}); }\n";
    assert!(scan_planted("readpath_a", "src/cache/read_path.rs", content, &THREADS).is_empty());
    assert_eq!(
        scan_planted("readpath_b", "src/cache/read_path2.rs", content, &THREADS),
        ["src/cache/read_path2.rs:1 `std::thread`"]
    );
}

// ---------------------------------------------------------------------
// Stripper unit tests: the lint must not fire on prose or literals.
// ---------------------------------------------------------------------

#[test]
fn stripper_ignores_comments_strings_and_char_literals() {
    let src = r##"
// std::sync::atomic in a line comment
/* std::thread in a /* nested */ block comment */
const A: &str = "std::time::Instant inside a string";
const R: &str = r#"unsafe inside a raw string"#;
const Q: char = '"'; // the quote char must not open a string
const N: &str = "after the quote char: std::sync::atomic";
"##;
    let stripped = strip_comments_and_strings(src);
    for rule in RULES {
        assert!(
            violations_in(&stripped, rule).is_empty(),
            "[{}] fired on stripped prose:\n{stripped}",
            rule.name
        );
    }
    // Lifetimes survive stripping (sanity that we only blank literals).
    let lt = strip_comments_and_strings("fn f<'a>(x: &'a u8) -> &'a u8 { x }");
    assert!(lt.contains("'a"), "lifetime was stripped: {lt}");
}

#[test]
fn stripper_keeps_line_numbers_stable() {
    let src = "line1\n/* c\nc */ std::thread::spawn\n";
    let stripped = strip_comments_and_strings(src);
    let v = violations_in(&stripped, &THREADS);
    assert_eq!(v, [(3, "std::thread")]);
}

#[test]
fn identifier_boundaries_prevent_false_positives() {
    // `unsafe_code` (the forbid attribute's token) is not `unsafe`, and
    // a made-up `not_std::thread` path prefix is still a real use of
    // `std::thread`? No — boundary on the left rejects it.
    let stripped = strip_comments_and_strings(
        "#![forbid(unsafe_code)]\nfn f() { my_std::thread_pool(); }\n",
    );
    assert!(violations_in(&stripped, &UNSAFE).is_empty());
    assert!(violations_in(&stripped, &THREADS).is_empty());
}
