//! Bench: regenerate the Fig 3 series (hit ratio vs cache size, LRU vs
//! H-SVM-LRU) and time the full sweep. Prints the paper-style rows.

use h_svm_lru::bench_support::{banner, Bencher};
use h_svm_lru::config::SvmConfig;
use h_svm_lru::experiments::fig3;

fn main() {
    banner("Fig 3 — cache hit ratio vs cache size (LRU vs H-SVM-LRU)");
    let svm_cfg = SvmConfig { backend: "rust".into(), ..Default::default() };
    let mut points = Vec::new();
    let res = Bencher::new(1, 5).run("fig3 full sweep (14 points, 2 policies)", || {
        points = fig3::run(&svm_cfg, 20230101).expect("fig3");
    });
    println!("{}", res.report());
    print!("{}", fig3::render(&points).render());

    // Paper-shape assertions double as regression checks in bench runs.
    let ir6 = points
        .iter()
        .find(|p| p.cache_blocks == 6 && p.block_size == 64 * 1024 * 1024)
        .map(|p| p.improvement_ratio())
        .unwrap_or(0.0);
    println!(
        "\nshape check: IR@6 blocks/64MB = {:.1}% (paper: 63.6%, largest of the sweep)",
        ir6 * 100.0
    );
    assert!(
        points.iter().all(|p| p.svm_lru >= p.lru - 1e-9),
        "H-SVM-LRU must dominate LRU"
    );
}
