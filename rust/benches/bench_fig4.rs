//! Bench: regenerate Fig 4 (WordCount execution time vs input size under
//! H-NoCache / H-LRU / H-SVM-LRU) and time the sweep.

use h_svm_lru::bench_support::{banner, Bencher};
use h_svm_lru::config::SvmConfig;
use h_svm_lru::experiments::fig4;

fn main() {
    banner("Fig 4 — job execution time vs input size");
    let svm_cfg = SvmConfig { backend: "rust".into(), ..Default::default() };
    let mut points = Vec::new();
    let res = Bencher::new(1, 3).run("fig4 sweep (10 points x 3 scenarios x 3 seeds)", || {
        points = fig4::run(&svm_cfg, 20230101).expect("fig4");
    });
    println!("{}", res.report());
    print!("{}", fig4::render(&points).render());

    // Shape checks: caching never loses to NoCache; the gap grows with
    // input size until the working set exceeds the cache.
    for p in &points {
        assert!(p.lru_s <= p.nocache_s * 1.02, "LRU must not lose to NoCache");
        assert!(p.svm_lru_s <= p.nocache_s * 1.02, "SVM-LRU must not lose to NoCache");
    }
    let big: Vec<_> = points
        .iter()
        .filter(|p| p.input_bytes >= 16 * 1024 * 1024 * 1024)
        .collect();
    assert!(
        big.iter().all(|p| p.svm_lru_s <= p.lru_s * 1.02),
        "beyond cache capacity SVM-LRU should dominate LRU"
    );
    println!("\nshape check passed: cached <= NoCache everywhere; SVM-LRU <= LRU beyond capacity");
}
