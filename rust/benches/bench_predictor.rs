//! Micro bench: SVM classifier latency — HLO artifacts through PJRT vs the
//! pure-Rust SMO, for training and batched prediction. This is the L1/L2
//! compute sitting on the L3 request path; the batcher amortizes the
//! per-call overhead measured here.

use h_svm_lru::bench_support::{banner, black_box, Bencher};
use h_svm_lru::runtime::{HloBackend, RustBackend, SvmBackend};
use h_svm_lru::svm::dataset::Dataset;
use h_svm_lru::svm::features::N_FEATURES;
use h_svm_lru::svm::KernelKind;
use h_svm_lru::util::rng::Pcg64;

fn blobs(n_per: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed, 0);
    let mut ds = Dataset::new();
    for _ in 0..n_per {
        let mut a = [0.0f32; N_FEATURES];
        let mut b = [0.0f32; N_FEATURES];
        for k in 0..N_FEATURES {
            a[k] = rng.gen_normal(0.3, 0.1) as f32;
            b[k] = rng.gen_normal(0.7, 0.1) as f32;
        }
        ds.push(a, true);
        ds.push(b, false);
    }
    ds
}

fn bench_backend(label: &str, backend: &mut dyn SvmBackend, ds: &Dataset) {
    let bench = Bencher::new(2, 10);
    let res = bench.run(&format!("{label}: train (n=256)"), || {
        backend.train(ds).expect("train");
    });
    println!("{}", res.report());
    let queries: Vec<[f32; N_FEATURES]> = ds.x[..64.min(ds.len())].to_vec();
    let res = bench.run_per_op(&format!("{label}: predict batch=64"), 64, || {
        black_box(backend.decision_batch(&queries).expect("predict"));
    });
    println!("{}", res.report());
    let one = &queries[..1];
    let res = bench.run(&format!("{label}: predict batch=1 (unbatched worst case)"), || {
        black_box(backend.decision_batch(one).expect("predict"));
    });
    println!("{}", res.report());
}

fn main() {
    banner("SVM backend latency — PJRT HLO artifacts vs pure-Rust SMO");
    let ds = blobs(128, 3);

    let mut smo = RustBackend::new(KernelKind::Rbf);
    bench_backend("rust/smo", &mut smo, &ds);

    match HloBackend::load("artifacts", KernelKind::Rbf) {
        Ok(mut hlo) => bench_backend("hlo/pjrt", &mut hlo, &ds),
        Err(e) => println!("(skipping HLO backend: {e:#} — run `make artifacts`)"),
    }
}
