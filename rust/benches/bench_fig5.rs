//! Bench: regenerate Fig 5 (normalized run time of workloads W1–W6) and
//! report the headline improvement numbers next to the paper's.

use h_svm_lru::bench_support::{banner, Bencher};
use h_svm_lru::config::SvmConfig;
use h_svm_lru::experiments::fig5;

fn main() {
    banner("Fig 5 — normalized run time of Table 8 workloads");
    let svm_cfg = SvmConfig { backend: "rust".into(), ..Default::default() };
    let mut points = Vec::new();
    let res = Bencher::new(0, 3).run("fig5 all workloads (6 x 3 scenarios x 5 seeds)", || {
        points = fig5::run(&svm_cfg, 20230101, fig5::DEFAULT_SCALE).expect("fig5");
    });
    println!("{}", res.report());
    print!("{}", fig5::render(&points).render());
    let (lru, svm, over) = fig5::summary(&points);
    println!("\nmeasured: H-LRU {lru:.2}%  H-SVM-LRU {svm:.2}%  (over LRU {over:.2}%)");
    println!("paper:    H-LRU 11.33%  H-SVM-LRU 16.16%  (over LRU 4.83%)");
    assert!(svm > lru - 1.0, "H-SVM-LRU should beat H-LRU on average");
    assert!(lru > 0.0, "caching should beat NoCache");
}
