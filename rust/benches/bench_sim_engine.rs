//! Micro bench: discrete-event engine throughput (events/sec) and the
//! end-to-end simulated-request rate of the coordinator — the L3 capacity
//! ceiling of the whole system.

use h_svm_lru::bench_support::{banner, black_box, Bencher};
use h_svm_lru::cache::CacheAffinity;
use h_svm_lru::config::SvmConfig;
use h_svm_lru::experiments::common::provision_fig3_cluster;
use h_svm_lru::experiments::{make_coordinator, Scenario};
use h_svm_lru::hdfs::{BlockId, BlockKind, DataNodeId};
use h_svm_lru::mapreduce::{AccessRequest, BlockService};
use h_svm_lru::sim::{Engine, SimDuration, SimTime};
use h_svm_lru::util::bytes::MB;

fn bench_engine() {
    const EVENTS: u64 = 200_000;
    let res = Bencher::micro().run_per_op("DES engine: schedule+fire chain", EVENTS, || {
        let mut eng: Engine<u64> = Engine::new();
        fn chain(eng: &mut Engine<u64>, count: &mut u64) {
            *count += 1;
            if *count % 2 == 0 {
                eng.schedule_in(SimDuration(3), chain);
            } else {
                eng.schedule_in(SimDuration(7), chain);
            }
        }
        let mut count = 0u64;
        eng.schedule_at(SimTime(0), chain);
        while count < EVENTS && eng.step(&mut count) {}
        black_box(count);
    });
    println!("{}", res.report());
}

fn bench_request_path(policy: &str) {
    const REQUESTS: u64 = 10_000;
    let svm_cfg = SvmConfig { backend: "rust".into(), ..Default::default() };
    let scenario = match policy {
        "h-svm-lru" => Scenario::SvmLru,
        p => Scenario::Policy(p.to_string()),
    };
    let res = Bencher::new(1, 5).run_per_op(
        &format!("coordinator read_block x{REQUESTS} ({policy})"),
        REQUESTS,
        || {
            let (_cfg, cluster) = provision_fig3_cluster(64 * MB, 8, 7);
            let mut coord = make_coordinator(cluster, &scenario, &svm_cfg).unwrap();
            let req = AccessRequest {
                app: "Grep".into(),
                affinity: CacheAffinity::High,
                kind: BlockKind::Input,
                file: 0,
                file_width: 32,
                file_complete: false,
            };
            for t in 0..REQUESTS {
                let b = BlockId((t * 31) % 32);
                black_box(coord.read_block(b, DataNodeId(0), SimTime(t * 100), &req));
            }
        },
    );
    println!("{}", res.report());
}

fn main() {
    banner("sim engine + request path throughput");
    bench_engine();
    for policy in ["lru", "h-svm-lru"] {
        bench_request_path(policy);
    }
}
