//! Bench: regenerate Fig 6 (per-application normalized run time inside
//! each workload under H-SVM-LRU).

use h_svm_lru::bench_support::{banner, Bencher};
use h_svm_lru::config::SvmConfig;
use h_svm_lru::experiments::{fig5, fig6};

fn main() {
    banner("Fig 6 — per-app normalized run time under H-SVM-LRU");
    let svm_cfg = SvmConfig { backend: "rust".into(), ..Default::default() };
    let mut points = Vec::new();
    let res = Bencher::new(0, 3).run("fig6 all workloads", || {
        points = fig6::run(&svm_cfg, 20230101, fig5::DEFAULT_SCALE).expect("fig6");
    });
    println!("{}", res.report());
    print!("{}", fig6::render(&points).render());
    let means = fig6::per_app_means(&points);
    println!("\nper-app means (ascending = best improvement first):");
    for (app, m) in &means {
        println!("  {app:<12} {m:.4}");
    }
    // Paper shape: multi-stage Join benefits least from input caching.
    let join = means.iter().find(|(a, _)| a == "Join").map(|(_, m)| *m).unwrap_or(1.0);
    let grep = means.iter().find(|(a, _)| a == "Grep").map(|(_, m)| *m).unwrap_or(1.0);
    assert!(join >= grep, "Join ({join:.3}) should benefit less than Grep ({grep:.3})");
    println!("\nshape check passed: Join benefits least (paper §6.4.2)");
}
