//! Shard-front scalability, measured — the two serialization points this
//! crate removed, held as regression lines:
//!
//! 1. **Reader contention.** `stats()` / `used()` used to take every
//!    shard `Mutex`: readers serialized against replay writers. With the
//!    seqlock stats block they are lock-free — the 8-thread replay wall
//!    must stay flat whether 0 or 4 reader threads hammer the stats path
//!    for its whole duration.
//! 2. **Miss-storm batcher stalls.** One global `PredictionBatcher`
//!    behind one lock made every shard worker wait for one synchronous
//!    backend flush; per-shard `ShardBatcher`s flush independently. The
//!    miss-storm scenario replays an all-cold query stream through both
//!    topologies.
//!
//! 3. **Locked LRU touches on hits.** Even a 100%-hit workload used to
//!    take the shard lock on every access just to move the block in the
//!    recency order. `ReadHandle` resolves hits against the seqlock
//!    read-view and batches the touches, so the warm-cache scenario's
//!    8-thread per-op wall must stay near its 1-thread wall.
//!
//! Plus the 1-vs-8-shard replay throughput baseline carried over from
//! `bench_policy_ops`.
//!
//! Flags: `--json` writes BENCH_sharded.json (compared against
//! `BENCH_baseline/BENCH_sharded.json` by the CI bench-gate job),
//! `--quick` drops to CI-smoke iteration counts.

use std::sync::Mutex;

use h_svm_lru::bench_support::{banner, black_box, write_json, Bencher};
use h_svm_lru::cache::sharded::shard_of;
use h_svm_lru::cache::{AccessContext, CacheBuilder, RecencyConfig, ShardedCache};
use h_svm_lru::coordinator::batcher::{BatcherConfig, PredictionBatcher, ShardBatcher};
use h_svm_lru::hdfs::BlockId;
use h_svm_lru::runtime::{RustBackend, SvmBackend};
use h_svm_lru::sim::parallel::{run_fanout, FanoutOptions};
use h_svm_lru::sim::SimTime;
use h_svm_lru::svm::features::{FeatureVec, N_FEATURES};
use h_svm_lru::svm::kernel::{KernelKind, KernelParams};
use h_svm_lru::svm::smo::SmoModel;
use h_svm_lru::util::rng::Pcg64;

const WORKERS: usize = 8;
const WORKING_SET: u64 = 256;

/// An `n`-shard lru cache of `capacity` size-1 blocks with the given
/// recency-buffer config — every scenario below goes through the builder.
fn lru_cache(shards: usize, capacity: u64, recency: RecencyConfig) -> ShardedCache {
    CacheBuilder::new()
        .policy("lru")
        .shards(shards)
        .capacity(capacity)
        .recency(recency)
        .build()
        .expect("lru cache")
}

/// One worker's deterministic slice of the replay stream (identical
/// regardless of the shard count, like `bench_policy_ops`).
fn replay_worker(cache: &ShardedCache, w: usize, ops: u64) {
    for t in 0..ops {
        let b = BlockId((w as u64 * 7919 + t * 31) % WORKING_SET);
        let ctx = AccessContext::simple(SimTime(t), 1).with_prediction(shard_of(b, 2) == 0);
        black_box(cache.access_or_insert(b, &ctx));
    }
}

fn bench_replay_shards(
    bench: &Bencher,
    ops: u64,
    results: &mut Vec<h_svm_lru::bench_support::BenchResult>,
) {
    banner("sharded front — 8 workers, 1 vs 8 shards (lru, 64-block cache)");
    let mut throughput = Vec::new();
    for shards in [1usize, 8] {
        let res = bench.run_per_op(
            &format!("replay lru {shards} shard(s), {WORKERS} threads"),
            ops * WORKERS as u64,
            || {
                let cache = lru_cache(shards, 64, RecencyConfig::immediate());
                run_fanout(WORKERS, |w| replay_worker(&cache, w, ops), FanoutOptions::new());
            },
        );
        println!("{}", res.report());
        throughput.push(res.mean);
        results.push(res);
    }
    println!(
        "\n8-shard speedup over 1-shard: {:.2}x (contended lock vs per-shard locks)",
        throughput[0].as_secs_f64() / throughput[1].as_secs_f64().max(1e-12)
    );
}

fn bench_reader_contention(
    bench: &Bencher,
    ops: u64,
    results: &mut Vec<h_svm_lru::bench_support::BenchResult>,
) {
    banner("reader contention — stats()/used() during the 8-thread replay");
    // Cost of one merged lock-free snapshot, uncontended.
    let cache = lru_cache(8, 64, RecencyConfig::immediate());
    run_fanout(WORKERS, |w| replay_worker(&cache, w, 1000), FanoutOptions::new());
    const READS: u64 = 100_000;
    let res = bench.run_per_op("stats snapshot read (merged, 8 shards)", READS, || {
        for _ in 0..READS {
            black_box(cache.stats());
            black_box(cache.used());
        }
    });
    println!("{}", res.report());
    results.push(res);

    // Replay wall with N reader threads looping the whole time. The
    // lock-free read path must leave the writers' wall flat: pre-split,
    // every snapshot took all 8 shard locks and the 4-reader row
    // collapsed.
    let mut walls = Vec::new();
    for readers in [0usize, 4] {
        let res = bench.run_per_op(
            &format!("replay 8 shards + {readers} stats readers"),
            ops * WORKERS as u64,
            || {
                let cache = lru_cache(8, 64, RecencyConfig::immediate());
                if readers == 0 {
                    run_fanout(
                        WORKERS,
                        |w| replay_worker(&cache, w, ops),
                        FanoutOptions::new(),
                    );
                } else {
                    let report = run_fanout(
                        WORKERS,
                        |w| replay_worker(&cache, w, ops),
                        FanoutOptions::new().monitor(
                            |done: &std::sync::atomic::AtomicBool| {
                                std::thread::scope(|scope| {
                                    let handles: Vec<_> = (0..readers)
                                        .map(|_| {
                                            scope.spawn(move || {
                                                let mut n = 0u64;
                                                while !done
                                                    .load(std::sync::atomic::Ordering::Acquire)
                                                {
                                                    black_box(cache.stats());
                                                    black_box(cache.used());
                                                    n += 1;
                                                }
                                                n
                                            })
                                        })
                                        .collect();
                                    handles
                                        .into_iter()
                                        .map(|h| h.join().expect("reader"))
                                        .sum::<u64>()
                                })
                            },
                        ),
                    );
                    black_box(report.monitor.expect("monitor configured"));
                }
            },
        );
        println!("{}", res.report());
        walls.push(res.mean);
        results.push(res);
    }
    println!(
        "\n4-reader slowdown over 0-reader: {:.2}x (lock-free readers must not serialize writers)",
        walls[1].as_secs_f64() / walls[0].as_secs_f64().max(1e-12)
    );
}

/// Worker loop of the hit-path scaling scenario: every access lands on a
/// resident block, so a [`h_svm_lru::cache::ReadHandle`] resolves it from
/// the seqlock read-view and only takes the shard lock to drain its
/// recency buffer (never, at batch 1, it drains inline under the lock).
fn hit_worker(cache: &ShardedCache, w: usize, ops: u64) {
    let mut handle = cache.read_handle();
    for t in 0..ops {
        let b = BlockId((w as u64 * 7919 + t * 31) % WORKING_SET);
        let ctx = AccessContext::simple(SimTime(t), 1).with_prediction(shard_of(b, 2) == 0);
        black_box(handle.access_or_insert(b, &ctx));
    }
}

fn bench_hit_path_scaling(
    bench: &Bencher,
    ops: u64,
    results: &mut Vec<h_svm_lru::bench_support::BenchResult>,
) {
    banner("lock-free hit path — warm 8-shard cache, batched recency readers");
    // The cache holds the whole working set, so after the single-threaded
    // warm-up every access is a hit. At batch 1 each hit still drains its
    // recency update under the shard lock (the bit-exact legacy path); at
    // batch 64 hits run lock-free and only every 64th access takes the
    // lock, so the 8-thread wall per op should stay near the 1-thread wall
    // (near-linear reader scaling).
    let mut per_op = Vec::new();
    for batch in [1usize, 64] {
        let recency =
            if batch == 1 { RecencyConfig::immediate() } else { RecencyConfig::default().with_batch(batch) };
        for threads in [1usize, WORKERS] {
            let res = bench.run_per_op(
                &format!("warm hit replay 8 shards, {threads} thread(s), recency batch {batch}"),
                ops * threads as u64,
                || {
                    let cache = lru_cache(8, WORKING_SET, recency);
                    for b in 0..WORKING_SET {
                        let ctx = AccessContext::simple(SimTime(b), 1)
                            .with_prediction(shard_of(BlockId(b), 2) == 0);
                        black_box(cache.access_or_insert(BlockId(b), &ctx));
                    }
                    run_fanout(threads, |w| hit_worker(&cache, w, ops), FanoutOptions::new());
                },
            );
            println!("{}", res.report());
            per_op.push(res.mean.as_secs_f64());
            results.push(res);
        }
    }
    // per_op = [batch1/1t, batch1/8t, batch64/1t, batch64/8t].
    println!(
        "\n8-thread per-op slowdown over 1-thread: batch 1 {:.2}x, batch 64 {:.2}x \
         (batched recency must keep the hot hit path near-linear)",
        per_op[1] / per_op[0].max(1e-12),
        per_op[3] / per_op[2].max(1e-12),
    );
}

/// A small synthetic linear model (decision cost independent of SVs).
fn synth_model(n_sv: usize, seed: u64) -> SmoModel {
    let mut rng = Pcg64::new(seed, 0xFA57);
    let mut x = Vec::with_capacity(n_sv);
    let mut y = Vec::with_capacity(n_sv);
    let mut alpha = Vec::with_capacity(n_sv);
    for i in 0..n_sv {
        let mut v = [0.0f32; N_FEATURES];
        for f in v.iter_mut() {
            *f = rng.next_f64() as f32;
        }
        x.push(v.to_vec());
        y.push(if i % 2 == 0 { 1.0 } else { -1.0 });
        alpha.push(0.1 + rng.next_f64() as f32);
    }
    SmoModel::new(KernelParams::new(KernelKind::Linear), x, y, alpha, 0.05)
}

fn query_features(w: usize, i: u64) -> FeatureVec {
    let mut f = [0.1f32; N_FEATURES];
    f[0] = ((w as u64 * 131 + i) % 97) as f32 / 97.0;
    f
}

fn bench_miss_storm(
    bench: &Bencher,
    queries: u64,
    results: &mut Vec<h_svm_lru::bench_support::BenchResult>,
) {
    banner("miss storm — all-cold queries: global batcher vs per-shard batchers");
    let model = synth_model(64, 11);
    let total = queries * WORKERS as u64;

    // Legacy topology: ONE batcher + ONE backend behind one lock. Every
    // cold query's synchronous flush happens inside the critical section,
    // so all 8 workers serialize on it.
    let res = bench.run_per_op(
        &format!("miss storm global batcher, {WORKERS} workers"),
        total,
        || {
            let mut backend = RustBackend::new(KernelKind::Linear);
            backend.import_model(model.clone()).expect("import");
            let global = Mutex::new((PredictionBatcher::new(64), backend));
            run_fanout(
                WORKERS,
                |w| {
                    for i in 0..queries {
                        // Unique block per query: every lookup is cold.
                        let block = BlockId(w as u64 * queries + i);
                        let f = query_features(w, i);
                        let mut g = global.lock().expect("global batcher");
                        let (batcher, backend) = &mut *g;
                        black_box(batcher.predict(backend, block, 0, f).expect("predict"));
                    }
                },
                FanoutOptions::new(),
            );
        },
    );
    println!("{}", res.report());
    let global_wall = res.mean;
    results.push(res);

    // Split topology: each worker (= shard) owns its batcher and backend;
    // a flush never leaves the worker.
    let res = bench.run_per_op(
        &format!("miss storm per-shard batchers, {WORKERS} workers"),
        total,
        || {
            run_fanout(
                WORKERS,
                |w| {
                    let mut backend = RustBackend::new(KernelKind::Linear);
                    backend.import_model(model.clone()).expect("import");
                    let mut batcher = ShardBatcher::new(BatcherConfig::default());
                    for i in 0..queries {
                        let block = BlockId(w as u64 * queries + i);
                        let f = query_features(w, i);
                        black_box(
                            batcher
                                .predict(&mut backend, block, 0, f, SimTime(i))
                                .expect("predict"),
                        );
                    }
                },
                FanoutOptions::new(),
            );
        },
    );
    println!("{}", res.report());
    let split_wall = res.mean;
    results.push(res);
    println!(
        "\nper-shard speedup over global: {:.2}x (no worker blocks behind another shard's flush)",
        global_wall.as_secs_f64() / split_wall.as_secs_f64().max(1e-12)
    );
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let json = args.iter().any(|a| a == "--json");
    let quick = args.iter().any(|a| a == "--quick");

    let bench = if quick { Bencher::new(1, 3) } else { Bencher::new(2, 10) };
    let ops: u64 = if quick { 2_000 } else { 10_000 };
    let queries: u64 = if quick { 2_000 } else { 10_000 };
    let mut results = Vec::new();

    bench_replay_shards(&bench, ops, &mut results);
    bench_reader_contention(&bench, ops, &mut results);
    bench_hit_path_scaling(&bench, ops, &mut results);
    bench_miss_storm(&bench, queries, &mut results);

    if json {
        let path = "BENCH_sharded.json";
        write_json(path, "sharded", &results).expect("writing bench json");
        println!("\nwrote {path} ({} results)", results.len());
    }
}
