//! Bench: regenerate Table 5 (kernel-function evaluation) and the §5.2
//! cross-validated accuracy; times dataset assembly + 3 kernel trainings.

use h_svm_lru::bench_support::{banner, Bencher};
use h_svm_lru::config::SvmConfig;
use h_svm_lru::experiments::table5;
use h_svm_lru::svm::KernelKind;

fn main() {
    banner("Table 5 — SVM kernel-function evaluation");
    let svm_cfg = SvmConfig { backend: "rust".into(), ..Default::default() };
    let mut evals = Vec::new();
    let res = Bencher::new(0, 3).run("table5 (dataset + 3 kernels, 75/25 split)", || {
        evals = table5::run(&svm_cfg, 20230101).expect("table5");
    });
    println!("{}", res.report());
    print!("{}", table5::render(&evals).render());

    let cv = table5::cross_validated_accuracy(&svm_cfg, 20230101, 4).expect("cv");
    println!("\n4-fold CV accuracy (rbf): {cv:.3}  (paper: ~0.83)");

    let acc = |k: KernelKind| evals.iter().find(|e| e.kernel == k).unwrap().test_accuracy;
    println!(
        "accuracies: linear {:.2}  rbf {:.2}  sigmoid {:.2}  (paper: 0.71 / 0.85 / 0.57)",
        acc(KernelKind::Linear),
        acc(KernelKind::Rbf),
        acc(KernelKind::Sigmoid)
    );
    assert!(acc(KernelKind::Rbf) >= acc(KernelKind::Sigmoid), "RBF must beat sigmoid");
}
