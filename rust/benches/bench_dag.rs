//! End-to-end cost of the DAG replay (`experiments::dag_replay`), held as
//! regression lines:
//!
//! 1. **A classifier-less pass is cheap.** One diamond-suite replay drives
//!    every stage through the MapReduce scheduler and the sharded cache —
//!    the whole pass must stay in event-loop territory, not blow up with
//!    the per-access cost plumbing (`AccessContext::recompute_cost`, the
//!    `CostAware` tie-break).
//! 2. **Classify-once stays two passes + one training.** The full
//!    `run_dag` adds ground-truth labeling, one SMO training over the
//!    pass-A log and a scored pass B; its wall is bounded by a small
//!    multiple of the classifier-less pass plus the train cost tracked in
//!    `bench_hotpath`.
//!
//! Flags: `--json` writes BENCH_dag.json (compared against
//! `BENCH_baseline/BENCH_dag.json` by the CI bench-gate job), `--quick`
//! drops to CI-smoke job counts.

use h_svm_lru::bench_support::{banner, black_box, write_json, Bencher};
use h_svm_lru::config::ClusterConfig;
use h_svm_lru::experiments::dag_replay::{run_dag, run_dag_pass};
use h_svm_lru::svm::kernel::KernelKind;
use h_svm_lru::workload::{chain_suite, diamond_suite};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let json = args.iter().any(|a| a == "--json");
    let quick = args.iter().any(|a| a == "--quick");

    let bench = if quick { Bencher::new(1, 3) } else { Bencher::new(2, 10) };
    let n_jobs = if quick { 2 } else { 4 };

    let cfg = ClusterConfig::default();
    let capacity = 16 * cfg.block_size;
    let seed = 7u64;
    let mut results = Vec::new();

    banner(&format!(
        "DAG replay — {n_jobs} concurrent jobs, 16-block cache, 4 shards"
    ));

    let diamond = diamond_suite(n_jobs, 4, 8);
    let res = bench.run("diamond pass, lru (no classifier)", || {
        black_box(
            run_dag_pass("lru", &cfg, 4, capacity, &diamond, seed, &[]).expect("replay"),
        );
    });
    println!("{}", res.report());
    results.push(res);

    let res = bench.run("diamond classify-once, h-svm-lru", || {
        black_box(
            run_dag("h-svm-lru", &cfg, 4, capacity, &diamond, seed, KernelKind::Rbf, 64)
                .expect("replay"),
        );
    });
    println!("{}", res.report());
    results.push(res);

    let chain = chain_suite(n_jobs, 3);
    let res = bench.run("chain pass, lru-cost tie-break", || {
        black_box(
            run_dag_pass("lru-cost", &cfg, 4, capacity, &chain, seed, &[]).expect("replay"),
        );
    });
    println!("{}", res.report());
    results.push(res);

    if json {
        let path = "BENCH_dag.json";
        write_json(path, "dag", &results).expect("writing bench json");
        println!("\nwrote {path} ({} results)", results.len());
    }
}
