//! Ablation bench: the design choices DESIGN.md calls out, each measured
//! against the same Fig 3 trace / cluster scenario.
//!
//! 1. kernel function (linear/rbf/sigmoid) -> end-to-end hit ratio,
//! 2. retrain interval -> hit ratio + training count,
//! 3. prefetch depth (0/1/2/4) -> hit ratio + prefetch usefulness,
//! 4. failure rates -> execution overhead under H-SVM-LRU vs LRU.

use h_svm_lru::bench_support::banner;
use h_svm_lru::config::{ClusterConfig, SvmConfig};
use h_svm_lru::experiments::common::provision_fig3_cluster;
use h_svm_lru::experiments::simulate::{self, SimulateConfig};
use h_svm_lru::experiments::{make_coordinator, replay_trace_two_pass, Scenario};
use h_svm_lru::mapreduce::FailureModel;
use h_svm_lru::util::bytes::MB;
use h_svm_lru::workload::fig3_trace;

const SEED: u64 = 20230101;

fn svm(kernel: &str) -> SvmConfig {
    SvmConfig { backend: "rust".into(), kernel: kernel.into(), ..Default::default() }
}

fn kernel_ablation() {
    banner("ablation 1 — kernel function vs end-to-end hit ratio");
    let trace = fig3_trace(64 * MB, SEED);
    for kernel in ["linear", "rbf", "sigmoid"] {
        let (_c, cluster) = provision_fig3_cluster(64 * MB, 8, SEED);
        let mut coord = make_coordinator(cluster, &Scenario::SvmLru, &svm(kernel)).unwrap();
        let hr = replay_trace_two_pass(&mut coord, &trace).unwrap();
        println!("kernel {kernel:<8} hit ratio {hr:.4}");
    }
}

fn retrain_interval_ablation() {
    banner("ablation 2 — retrain cadence (simulate, 16 jobs)");
    // The pipeline retrain interval is fixed at coordinator construction;
    // vary the training signal instead via job count per training epoch
    // by changing arrival rate (denser arrivals = fewer retrain chances
    // between jobs).
    for mean_gap in [5.0, 20.0, 60.0] {
        let cfg = ClusterConfig { datanodes: 3, replication: 2, ..Default::default() };
        let sim = SimulateConfig {
            n_jobs: 16,
            mean_interarrival_s: mean_gap,
            seed: SEED,
            ..Default::default()
        };
        let r = simulate::run(&cfg, &Scenario::SvmLru, &svm("rbf"), &sim).unwrap();
        println!(
            "arrival gap {mean_gap:>5.0}s  trainings {:>2}  hit ratio {:.4}",
            r.trainings, r.hit_ratio
        );
    }
}

fn prefetch_ablation() {
    banner("ablation 3 — prefetch depth (paper §7 future work)");
    for depth in [0u32, 1, 2, 4] {
        let cfg = ClusterConfig { datanodes: 3, replication: 2, ..Default::default() };
        let sim = SimulateConfig {
            n_jobs: 16,
            prefetch_depth: depth,
            seed: SEED,
            ..Default::default()
        };
        let r = simulate::run(&cfg, &Scenario::SvmLru, &svm("rbf"), &sim).unwrap();
        let useful = r
            .prefetch_useful
            .map(|u| format!("{:.0}%", u * 100.0))
            .unwrap_or_else(|| "-".into());
        let times: Vec<f64> = r
            .completed
            .iter()
            .map(|j| j.execution_time().as_secs_f64())
            .collect();
        println!(
            "depth {depth}  hit ratio {:.4}  useful {useful:>4}  mean exec {:.1}s",
            r.hit_ratio,
            h_svm_lru::util::stats::mean(&times)
        );
    }
}

fn failure_ablation() {
    banner("ablation 4 — failure injection overhead");
    for (fail, kill) in [(0.0, 0.0), (0.05, 0.02), (0.15, 0.05)] {
        let cfg = ClusterConfig { datanodes: 3, replication: 2, ..Default::default() };
        let sim = SimulateConfig {
            n_jobs: 12,
            failures: FailureModel::with_rates(fail, kill, SEED),
            seed: SEED,
            ..Default::default()
        };
        let r = simulate::run(&cfg, &Scenario::SvmLru, &svm("rbf"), &sim).unwrap();
        let times: Vec<f64> = r
            .completed
            .iter()
            .map(|j| j.execution_time().as_secs_f64())
            .collect();
        println!(
            "fail {fail:.2}/kill {kill:.2}  attempts lost {:>3}  mean exec {:.1}s  hit ratio {:.4}",
            r.failed_attempts + r.killed_attempts,
            h_svm_lru::util::stats::mean(&times),
            r.hit_ratio
        );
    }
}

fn main() {
    kernel_ablation();
    retrain_interval_ablation();
    prefetch_ablation();
    failure_ablation();
}
