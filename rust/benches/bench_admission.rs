//! Admission-layer hot-path cost: the frequency sketch + doorkeeper sit on
//! every request of every shard, so their per-op overhead over the bare
//! `always` path bounds what admission control may cost at scale. 8-shard
//! concurrent replay (one scoped worker per shard's keyspace slice),
//! admission on/off, for the LRU baseline and the paper's H-SVM-LRU.

use h_svm_lru::bench_support::{banner, black_box, Bencher};
use h_svm_lru::cache::sharded::{shard_of, ShardedCache};
use h_svm_lru::cache::{AccessContext, CacheBuilder};
use h_svm_lru::hdfs::BlockId;
use h_svm_lru::sim::parallel::{run_fanout, FanoutOptions};
use h_svm_lru::sim::SimTime;

const OPS_PER_WORKER: u64 = 10_000;
const WORKERS: usize = 8;
const SHARDS: usize = 8;
const WORKING_SET: u64 = 256;

fn replay(cache: &ShardedCache) {
    run_fanout(
        WORKERS,
        |w| {
            // Each worker owns a disjoint block range, so no two workers
            // ever touch the same block and the stream content is identical
            // across admission policies; residual contention is only
            // shard-routing overlap, the same for every policy under test.
            for t in 0..OPS_PER_WORKER {
                let b = BlockId(w as u64 * WORKING_SET + (t * 31) % WORKING_SET);
                let ctx = AccessContext::simple(SimTime(t), 1)
                    .with_prediction(shard_of(b, 2) == 0);
                black_box(cache.access_or_insert(b, &ctx));
            }
        },
        FanoutOptions::new(),
    );
}

fn main() {
    banner("admission hot path — 8 workers, 8 shards, 64-block cache");
    let bench = Bencher::new(2, 10);
    let ops = OPS_PER_WORKER * WORKERS as u64;
    let mut baseline = None;
    for policy in ["lru", "h-svm-lru"] {
        for admission in ["always", "tinylfu", "ghost", "svm"] {
            let res = bench.run_per_op(&format!("{policy} + {admission}"), ops, || {
                let cache = CacheBuilder::new()
                    .policy(policy)
                    .admission(admission)
                    .shards(SHARDS)
                    .capacity(64)
                    .build()
                    .expect("cache under test");
                replay(&cache);
                black_box(cache.hit_ratio());
            });
            println!("{}", res.report());
            if admission == "always" {
                baseline = Some(res.mean);
            } else if let Some(base) = baseline {
                println!(
                    "    {admission} / always overhead: {:.2}x",
                    res.mean.as_secs_f64() / base.as_secs_f64().max(1e-12)
                );
            }
        }
    }
}
