//! Bench: regenerate Table 7 (improvement ratio of H-SVM-LRU over LRU per
//! cache size, from the Fig 3 series).

use h_svm_lru::bench_support::{banner, Bencher};
use h_svm_lru::config::SvmConfig;
use h_svm_lru::experiments::table7;

fn main() {
    banner("Table 7 — improvement ratio of H-SVM-LRU over LRU");
    let svm_cfg = SvmConfig { backend: "rust".into(), ..Default::default() };
    let mut points = Vec::new();
    let res = Bencher::new(0, 3).run("table7 (fig3 sweep + IR derivation)", || {
        points = table7::run(&svm_cfg, 20230101).expect("table7");
    });
    println!("{}", res.report());
    print!("{}", table7::render(&points).render());

    // Paper shape: the improvement is largest for small caches and small
    // blocks ("H-SVM-LRU is suitable for small cache size").
    let ir = |blocks: u64, bs: u64| {
        points
            .iter()
            .find(|p| p.cache_blocks == blocks && p.block_size == bs)
            .map(|p| p.improvement_ratio())
            .unwrap_or(0.0)
    };
    let mb = 1024 * 1024;
    let small = ir(6, 64 * mb);
    let large = ir(24, 64 * mb);
    println!(
        "\nshape check: IR small cache {:.1}% vs large cache {:.1}% (paper: 63.6% -> 7.9%)",
        small * 100.0,
        large * 100.0
    );
    assert!(small > large, "IR must shrink as the cache grows");
    assert!(ir(6, 64 * mb) > ir(6, 128 * mb), "64MB blocks show larger IR (paper)");
}
