//! The O(1) eviction hot path + SVM fast-path inference, measured.
//!
//! Two claims to hold the line on (this file is the recorded baseline all
//! future perf PRs are judged against):
//!
//! 1. **touch/insert/evict are O(1)**: per-op latency of the ported
//!    policies (OrderList-backed LRU / H-SVM-LRU / Modified-ARC, plus the
//!    ghost-admission LRU) stays flat — within noise — as the resident
//!    population grows 1k → 1M blocks. The BTreeMap implementations this
//!    replaced degraded with O(log n) re-keying per access.
//! 2. **linear-kernel `decision` is O(d)**: the precomputed weight vector
//!    makes the score independent of the support-vector count (64 → 4096
//!    SVs, flat), while RBF — which must keep the kernel loop — scales
//!    linearly over the contiguous SoA slab.
//!
//! Plus decisions/sec through `RustBackend::decision_batch` and the SMO
//! train cost (error-cache path).
//!
//! Flags: `--json` writes BENCH_hotpath.json via `bench_support::
//! write_json` (uploaded by the CI bench-record job), `--quick` drops to
//! CI-smoke sizes/iteration counts.

use h_svm_lru::bench_support::{banner, black_box, write_json, BenchResult, Bencher};
use h_svm_lru::cache::admission::GhostProbation;
use h_svm_lru::cache::registry::make_policy;
use h_svm_lru::cache::{AccessContext, BlockCache, CacheBuilder};
use h_svm_lru::hdfs::BlockId;
use h_svm_lru::runtime::{RustBackend, SvmBackend};
use h_svm_lru::sim::SimTime;
use h_svm_lru::svm::features::{FeatureVec, N_FEATURES};
use h_svm_lru::svm::kernel::{KernelKind, KernelParams};
use h_svm_lru::svm::smo::{train, SmoConfig, SmoModel};
use h_svm_lru::svm::Dataset;
use h_svm_lru::util::rng::Pcg64;

/// Mixed touch/insert/evict stream at a fixed resident population:
/// even ops touch a likely-resident block (hit → policy re-order), odd ops
/// insert a never-seen block (miss → insert + one eviction at capacity).
struct HotPath {
    cache: BlockCache,
    resident: u64,
    now: u64,
    cold: u64,
}

impl HotPath {
    fn new(policy: &str, ghost: bool, resident: u64) -> Self {
        let cache = if ghost {
            // Ghost probation sized to the population: every rejected
            // first sighting and every eviction churns the ghost LRU.
            CacheBuilder::new()
                .policy(policy)
                .admission_with(move || Box::new(GhostProbation::new(resident as usize)))
                .capacity(resident)
                .build_block_cache()
                .expect("registry policy")
        } else {
            BlockCache::new(make_policy(policy).expect("registry policy"), resident)
        };
        let mut hp = HotPath { cache, resident, now: 0, cold: 0 };
        // Prefill to capacity so every odd op evicts (two rounds: ghost
        // admission needs each id twice to graduate probation).
        for i in 0..2 * resident {
            hp.step_block(i % resident);
        }
        hp.cold = resident;
        hp
    }

    fn step_block(&mut self, id: u64) {
        let ctx = AccessContext::simple(SimTime(self.now), 1)
            .with_prediction(id % 3 != 0);
        black_box(self.cache.access_or_insert(BlockId(id), &ctx));
        self.now += 1;
    }

    /// One measured op (the 7919 stride decorrelates the hot-id walk).
    fn step(&mut self, t: u64) {
        let id = if t % 2 == 0 {
            // Likely-resident id: recently inserted cold ids stay cached
            // until ~`resident` newer inserts push them out.
            let back = 1 + t.wrapping_mul(7919) % self.resident;
            self.cold.saturating_sub(back)
        } else {
            self.cold += 1;
            self.cold
        };
        self.step_block(id);
    }
}

fn bench_policies(bench: &Bencher, quick: bool, results: &mut Vec<BenchResult>) {
    banner("eviction hot path — touch/insert/evict mix vs resident blocks");
    let ops: u64 = if quick { 20_000 } else { 100_000 };
    let sizes: &[u64] = if quick {
        &[1_000, 32_768]
    } else {
        &[1_000, 32_768, 1_000_000]
    };
    let configs: &[(&str, bool)] = &[
        ("lru", false),
        ("h-svm-lru", false),
        ("modified-arc", false),
        ("lru", true), // + ghost-probation admission
    ];
    for &(policy, ghost) in configs {
        let label = if ghost {
            format!("{policy}+ghost")
        } else {
            policy.to_string()
        };
        for &resident in sizes {
            let mut hp = HotPath::new(policy, ghost, resident);
            let r = bench.run_per_op(
                &format!("{label} access mix, {resident} resident"),
                ops,
                || {
                    for t in 0..ops {
                        hp.step(t);
                    }
                },
            );
            println!("{}", r.report());
            results.push(r);
        }
    }
    println!("\nO(1) check: per-op latency must stay flat (within noise) down each column.");
}

/// A synthetic dual model with `n_sv` active support vectors.
fn synth_model(kind: KernelKind, n_sv: usize, seed: u64) -> SmoModel {
    let mut rng = Pcg64::new(seed, 0xFA57);
    let mut x = Vec::with_capacity(n_sv);
    let mut y = Vec::with_capacity(n_sv);
    let mut alpha = Vec::with_capacity(n_sv);
    for i in 0..n_sv {
        let mut v = [0.0f32; N_FEATURES];
        for f in v.iter_mut() {
            *f = rng.next_f64() as f32;
        }
        x.push(v.to_vec());
        y.push(if i % 2 == 0 { 1.0 } else { -1.0 });
        alpha.push(0.1 + rng.next_f64() as f32);
    }
    SmoModel::new(KernelParams::new(kind), x, y, alpha, 0.05)
}

fn bench_svm(bench: &Bencher, quick: bool, results: &mut Vec<BenchResult>) {
    banner("SVM inference — decision latency vs support-vector count");
    let evals: u64 = if quick { 5_000 } else { 50_000 };
    let query = [0.4f32; N_FEATURES];
    for kind in [KernelKind::Linear, KernelKind::Rbf] {
        for n_sv in [64usize, 512, 4096] {
            let model = synth_model(kind, n_sv, 11);
            let r = bench.run_per_op(
                &format!("{} decision, {n_sv} sv", kind.name()),
                evals,
                || {
                    for _ in 0..evals {
                        black_box(model.decision(&query));
                    }
                },
            );
            println!("{}", r.report());
            results.push(r);
        }
    }
    println!("\nO(1) check: linear decision must not scale with the sv count (rbf does).");

    banner("SVM batch inference — decisions/sec through RustBackend");
    let batch: Vec<FeatureVec> = {
        let mut rng = Pcg64::new(3, 0xBA7C);
        (0..1024)
            .map(|_| {
                let mut f = [0.0f32; N_FEATURES];
                for v in f.iter_mut() {
                    *v = rng.next_f64() as f32;
                }
                f
            })
            .collect()
    };
    for kind in [KernelKind::Linear, KernelKind::Rbf] {
        let mut backend = RustBackend::new(kind);
        backend
            .import_model(synth_model(kind, 256, 17))
            .expect("rust backend imports snapshots");
        let r = bench.run_per_op(
            &format!("decision_batch 1024q, {} 256sv", kind.name()),
            1024,
            || {
                black_box(backend.decision_batch(&batch).expect("batch scores"));
            },
        );
        println!("{}", r.report());
        results.push(r);
    }
}

fn bench_train(bench: &Bencher, quick: bool, results: &mut Vec<BenchResult>) {
    banner("SMO training — error-cache path");
    let n_per = if quick { 64 } else { 128 };
    let mut rng = Pcg64::new(21, 0);
    let mut ds = Dataset::new();
    for _ in 0..n_per {
        let mut a = [0.0f32; N_FEATURES];
        let mut b = [0.0f32; N_FEATURES];
        for k in 0..N_FEATURES {
            a[k] = rng.gen_normal(0.3, 0.1) as f32;
            b[k] = rng.gen_normal(0.7, 0.1) as f32;
        }
        ds.push(a, true);
        ds.push(b, false);
    }
    let r = bench.run(&format!("smo::train rbf, {} samples", ds.len()), || {
        black_box(train(&ds, KernelParams::new(KernelKind::Rbf), &SmoConfig::default()));
    });
    println!("{}", r.report());
    results.push(r);
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let json = args.iter().any(|a| a == "--json");
    let quick = args.iter().any(|a| a == "--quick");

    let bench = if quick { Bencher::new(1, 3) } else { Bencher::new(2, 10) };
    let mut results = Vec::new();

    bench_policies(&bench, quick, &mut results);
    bench_svm(&bench, quick, &mut results);
    bench_train(&bench, quick, &mut results);

    if json {
        let path = "BENCH_hotpath.json";
        write_json(path, "hotpath", &results).expect("writing bench json");
        println!("\nwrote {path} ({} results)", results.len());
    }
}
