//! Telemetry overhead gate — the observability acceptance criterion held
//! as a bench: an 8-shard h-svm-lru fig3 replay with the full metrics
//! stack enabled (registry histograms + windowed series + audit ring)
//! must stay close to the same replay with telemetry off, and a disabled
//! registry must be a near-zero-cost no-op on the hot path.
//!
//! Flags: `--json` writes BENCH_obs.json (compared against
//! `BENCH_baseline/BENCH_obs.json` by the CI bench-gate job), `--quick`
//! drops to CI-smoke iteration counts. The metrics-on/metrics-off ratio
//! is always printed; set `BENCH_OBS_STRICT=1` to turn the 5% budget into
//! a hard assertion (shared CI runners are too noisy to enforce it on
//! every build, the bench-gate min_ns lines are the durable guard).

use h_svm_lru::bench_support::{banner, black_box, write_json, Bencher};
use h_svm_lru::cache::{CacheBuilder, ShardedCache};
use h_svm_lru::experiments::sharded_replay::{classify_trace_scored, drive, ReplayOptions};
use h_svm_lru::obs::{MetricsRegistry, ObsConfig};
use h_svm_lru::svm::KernelKind;
use h_svm_lru::util::bytes::MB;
use h_svm_lru::workload::fig3_trace;

const SHARDS: usize = 8;

fn svm_cache(capacity: u64) -> ShardedCache {
    CacheBuilder::new()
        .policy("h-svm-lru")
        .shards(SHARDS)
        .capacity(capacity)
        .build()
        .expect("h-svm-lru cache")
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let json = args.iter().any(|a| a == "--json");
    let quick = args.iter().any(|a| a == "--quick");

    let bench = if quick { Bencher::new(1, 3) } else { Bencher::new(2, 10) };
    let repeats: u64 = if quick { 4 } else { 16 };

    let trace = fig3_trace(64 * MB, 11);
    let (features, scores) =
        classify_trace_scored(&trace, KernelKind::Rbf, 64).expect("classifier pass");
    let classes: Vec<Option<bool>> = scores.iter().map(|s| s.map(|v| v > 0.0)).collect();
    let capacity = 8 * 64 * MB;
    let ops = trace.len() as u64 * repeats;
    let mut results = Vec::new();

    banner("telemetry overhead — 8-shard h-svm-lru fig3 replay, metrics off vs on");

    let res = bench.run_per_op("observed replay, metrics off", ops, || {
        for _ in 0..repeats {
            let cache = svm_cache(capacity);
            let opts = ReplayOptions::new().classes(&classes);
            black_box(drive(&cache, &trace, &opts).expect("replay"));
        }
    });
    println!("{}", res.report());
    let off_wall = res.mean;
    results.push(res);

    let res = bench.run_per_op("observed replay, disabled registry", ops, || {
        for _ in 0..repeats {
            let cache = svm_cache(capacity);
            let registry = MetricsRegistry::disabled();
            let opts = ReplayOptions::new()
                .scored(&features, &scores)
                .observe(&registry, ObsConfig::default());
            black_box(drive(&cache, &trace, &opts).expect("replay"));
        }
    });
    println!("{}", res.report());
    results.push(res);

    let res = bench.run_per_op("observed replay, metrics on", ops, || {
        for _ in 0..repeats {
            let cache = svm_cache(capacity);
            let registry = MetricsRegistry::new();
            let opts = ReplayOptions::new()
                .scored(&features, &scores)
                .observe(&registry, ObsConfig::default());
            black_box(drive(&cache, &trace, &opts).expect("replay"));
        }
    });
    println!("{}", res.report());
    let on_wall = res.mean;
    results.push(res);

    let overhead = on_wall.as_secs_f64() / off_wall.as_secs_f64().max(1e-12);
    println!("\nmetrics-on overhead over metrics-off: {overhead:.3}x (budget: 1.05x)");
    if std::env::var_os("BENCH_OBS_STRICT").is_some() {
        assert!(
            overhead <= 1.05,
            "telemetry overhead {overhead:.3}x exceeds the 5% acceptance budget"
        );
    }

    if json {
        let path = "BENCH_obs.json";
        write_json(path, "obs", &results).expect("writing bench json");
        println!("\nwrote {path} ({} results)", results.len());
    }
}
