//! Micro bench: per-operation latency of every cache replacement policy on
//! the L3 hot path (access + insert + evict mix). The coordinator calls
//! these on every block request, so ns/op here bounds request throughput.

use h_svm_lru::bench_support::{banner, black_box, Bencher};
use h_svm_lru::cache::registry::{make_policy, POLICY_NAMES};
use h_svm_lru::cache::sharded::shard_of;
use h_svm_lru::cache::{AccessContext, BlockCache, CacheBuilder};
use h_svm_lru::hdfs::BlockId;
use h_svm_lru::sim::parallel::{run_fanout, FanoutOptions};
use h_svm_lru::sim::SimTime;

/// Baseline perf trajectory point: 1-shard vs 8-shard throughput with 8
/// worker threads hammering the same front. One shard serializes every
/// access on a single lock; eight shards give each worker a private lock,
/// so the ratio is the headroom sharding buys future scaling PRs.
fn bench_sharded() {
    banner("sharded front — 8 workers, 1 vs 8 shards (lru, 64-block cache)");
    const OPS_PER_WORKER: u64 = 10_000;
    const WORKERS: usize = 8;
    const WORKING_SET: u64 = 256;
    let bench = Bencher::new(2, 10);
    let mut throughput = Vec::new();
    for shards in [1usize, 8] {
        let res = bench.run_per_op(
            &format!("lru x{shards} shard(s), {WORKERS} threads"),
            OPS_PER_WORKER * WORKERS as u64,
            || {
                let cache = CacheBuilder::new()
                    .policy("lru")
                    .shards(shards)
                    .capacity(64)
                    .build()
                    .expect("lru cache");
                run_fanout(
                    WORKERS,
                    |w| {
                        // Each worker walks its own slice of the keyspace so
                        // the stream is identical regardless of the shard
                        // count.
                        for t in 0..OPS_PER_WORKER {
                            let b = BlockId((w as u64 * 7919 + t * 31) % WORKING_SET);
                            let ctx = AccessContext::simple(SimTime(t), 1)
                                .with_prediction(shard_of(b, 2) == 0);
                            black_box(cache.access_or_insert(b, &ctx));
                        }
                    },
                    FanoutOptions::new(),
                );
            },
        );
        println!("{}", res.report());
        throughput.push((shards, res.mean));
    }
    let one = throughput[0].1.as_secs_f64();
    let eight = throughput[1].1.as_secs_f64();
    println!(
        "\n8-shard speedup over 1-shard: {:.2}x (contended lock vs per-shard locks)",
        one / eight.max(1e-12)
    );
}

fn main() {
    banner("policy micro ops — mixed access workload, 64-block cache");
    const OPS: u64 = 20_000;
    const WORKING_SET: u64 = 256;
    let bench = Bencher::micro();
    let mut results = Vec::new();
    for &name in POLICY_NAMES {
        let res = bench.run_per_op(name, OPS, || {
            let mut cache = BlockCache::new(make_policy(name).unwrap(), 64);
            for t in 0..OPS {
                // Deterministic mixed stream: zipf-ish hot spots + scans.
                let b = if t % 3 == 0 { t % 8 } else { (t * 7919) % WORKING_SET };
                let ctx = AccessContext::simple(SimTime(t), 1)
                    .with_prediction(b < WORKING_SET / 2);
                black_box(cache.access_or_insert(BlockId(b), &ctx));
            }
        });
        println!("{}", res.report());
        results.push((name, res.mean));
    }
    // The paper's own policy must not be an outlier vs plain LRU.
    let lru = results.iter().find(|(n, _)| *n == "lru").unwrap().1;
    let hsvm = results.iter().find(|(n, _)| *n == "h-svm-lru").unwrap().1;
    println!(
        "\nh-svm-lru / lru overhead: {:.2}x",
        hsvm.as_secs_f64() / lru.as_secs_f64()
    );

    bench_sharded();
}
