//! Micro bench: per-operation latency of every cache replacement policy on
//! the L3 hot path (access + insert + evict mix). The coordinator calls
//! these on every block request, so ns/op here bounds request throughput.

use h_svm_lru::bench_support::{banner, black_box, Bencher};
use h_svm_lru::cache::registry::{make_policy, POLICY_NAMES};
use h_svm_lru::cache::{AccessContext, BlockCache};
use h_svm_lru::hdfs::BlockId;
use h_svm_lru::sim::SimTime;

fn main() {
    banner("policy micro ops — mixed access workload, 64-block cache");
    const OPS: u64 = 20_000;
    const WORKING_SET: u64 = 256;
    let bench = Bencher::micro();
    let mut results = Vec::new();
    for &name in POLICY_NAMES {
        let res = bench.run_per_op(name, OPS, || {
            let mut cache = BlockCache::new(make_policy(name).unwrap(), 64);
            for t in 0..OPS {
                // Deterministic mixed stream: zipf-ish hot spots + scans.
                let b = if t % 3 == 0 { t % 8 } else { (t * 7919) % WORKING_SET };
                let ctx = AccessContext::simple(SimTime(t), 1)
                    .with_prediction(b < WORKING_SET / 2);
                black_box(cache.access_or_insert(BlockId(b), &ctx));
            }
        });
        println!("{}", res.report());
        results.push((name, res.mean));
    }
    // The paper's own policy must not be an outlier vs plain LRU.
    let lru = results.iter().find(|(n, _)| *n == "lru").unwrap().1;
    let hsvm = results.iter().find(|(n, _)| *n == "h-svm-lru").unwrap().1;
    println!(
        "\nh-svm-lru / lru overhead: {:.2}x",
        hsvm.as_secs_f64() / lru.as_secs_f64()
    );
}
