//! Online-learning hot-path costs: what the concurrent replay pays for a
//! live classifier instead of a frozen one.
//!
//! * **snapshot read** — `SnapshotReader::predict` on an unchanged model
//!   (one atomic load + the kernel evaluation) vs. the raw
//!   `SmoModel::decision` floor;
//! * **publish latency** — `SnapshotCell::publish` (model clone into a
//!   fresh `Arc` + version bump under the slot lock);
//! * **sample throughput** — emit → bounded channel → trainer drain with
//!   on-cadence SMO retraining, end to end;
//! * **replay** — the 8-shard fig3 replay, frozen vs. online.
//!
//! Flags: `--json` writes BENCH_online.json (machine-readable record for
//! the perf trajectory; see `bench_support::write_json`), `--quick`
//! drops to CI-smoke iteration counts.

use std::sync::Arc;

use h_svm_lru::bench_support::{banner, black_box, write_json, Bencher};
use h_svm_lru::cache::RecencyConfig;
use h_svm_lru::coordinator::batcher::BatcherConfig;
use h_svm_lru::coordinator::online::{
    sample_channel, trainer_loop, SnapshotCell, TrainerConfig,
};
use h_svm_lru::coordinator::TrainingPipeline;
use h_svm_lru::experiments::online_sharded::{pretrain_model, run_online, TrainerMode};
use h_svm_lru::runtime::RustBackend;
use h_svm_lru::svm::features::N_FEATURES;
use h_svm_lru::svm::KernelKind;
use h_svm_lru::util::bytes::MB;
use h_svm_lru::workload::fig3_trace;

const BLOCK: u64 = 64 * MB;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let json = args.iter().any(|a| a == "--json");
    let quick = args.iter().any(|a| a == "--quick");

    banner("online learning — snapshot reads, publish latency, samples/sec");
    let bench = if quick { Bencher::new(1, 3) } else { Bencher::new(2, 10) };
    let mut results = Vec::new();

    let trace = fig3_trace(BLOCK, 7);
    let model = pretrain_model(&trace, KernelKind::Rbf)
        .expect("pretraining fig3")
        .expect("fig3 trace is two-class");
    let features = [0.3f32; N_FEATURES];

    // Snapshot-read overhead: reader vs. the raw-model floor.
    const READS: u64 = 100_000;
    let cell = Arc::new(SnapshotCell::new());
    cell.publish(model.clone());
    let mut reader = cell.reader();
    let r = bench.run_per_op("snapshot read + predict (unchanged model)", READS, || {
        for _ in 0..READS {
            black_box(reader.predict(&features));
        }
    });
    println!("{}", r.report());
    results.push(r);
    let r = bench.run_per_op("raw SmoModel::decision (floor)", READS, || {
        for _ in 0..READS {
            black_box(model.decision(&features));
        }
    });
    println!("{}", r.report());
    results.push(r);

    // Publish latency: clone + Arc swap + version bump per publish.
    const PUBLISHES: u64 = 256;
    let r = bench.run_per_op("snapshot publish (clone + swap)", PUBLISHES, || {
        for _ in 0..PUBLISHES {
            black_box(cell.publish(model.clone()));
        }
    });
    println!("{}", r.report());
    results.push(r);

    // Sample throughput: emit -> channel -> trainer drain with retrains.
    let samples: u64 = if quick { 512 } else { 2048 };
    let r = bench.run_per_op(
        &format!("sample channel + trainer drain ({samples} samples)"),
        samples,
        || {
            let (tx, rx) = sample_channel(8192);
            let cell = Arc::new(SnapshotCell::new());
            let trainer_cell = Arc::clone(&cell);
            let trainer = std::thread::spawn(move || {
                let mut backend = RustBackend::new(KernelKind::Rbf);
                let mut pipeline = TrainingPipeline::new(64, 256);
                trainer_loop(rx, &mut backend, &mut pipeline, &trainer_cell)
                    .expect("trainer loop")
            });
            for i in 0..samples {
                let mut f = [0.0f32; N_FEATURES];
                let reused = i % 2 == 0;
                f[0] = if reused { 0.2 } else { 0.8 };
                tx.emit(f, reused);
            }
            drop(tx);
            let report = trainer.join().expect("trainer thread");
            black_box(report.publishes);
        },
    );
    println!("{}", r.report());
    results.push(r);

    // End to end: the 8-shard fig3 replay, frozen vs. live trainer.
    for mode in [TrainerMode::Frozen, TrainerMode::Online] {
        let r = bench.run(&format!("fig3 8-shard h-svm-lru replay, {}", mode.label()), || {
            let report = run_online(
                "h-svm-lru",
                8,
                8 * BLOCK,
                &trace,
                mode,
                KernelKind::Rbf,
                TrainerConfig::default(),
                BatcherConfig::default(),
                RecencyConfig::default(),
            )
            .expect("online replay");
            black_box(report.hit_ratio());
        });
        println!("{}", r.report());
        results.push(r);
    }

    if json {
        let path = "BENCH_online.json";
        write_json(path, "online", &results).expect("writing bench json");
        println!("\nwrote {path} ({} results)", results.len());
    }
}
