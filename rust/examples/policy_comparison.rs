//! All 13 registered replacement policies replayed over the same Fig 3
//! trace — the Table 1 survey as a runnable ablation. CI runs this as a
//! smoke test to catch drift in the policy registry and experiment APIs.
//!
//! ```text
//! cargo run --release --example policy_comparison
//! ```

use anyhow::Result;

use h_svm_lru::cache::registry::POLICY_NAMES;
use h_svm_lru::config::SvmConfig;
use h_svm_lru::experiments::policies;

fn main() -> Result<()> {
    let svm_cfg = SvmConfig { backend: "rust".into(), ..Default::default() };
    let cache_blocks = 8;
    let results = policies::run(&svm_cfg, 20230101, cache_blocks)?;
    println!(
        "\n=== Policy ablation (cache = {cache_blocks} blocks of 64MB, {} policies) ===",
        results.len()
    );
    print!("{}", policies::render(&results).render());
    anyhow::ensure!(
        results.len() == POLICY_NAMES.len(),
        "ablation covered {} of {} registered policies",
        results.len(),
        POLICY_NAMES.len()
    );
    println!("\nOK: every registered policy replayed the trace.");
    Ok(())
}
