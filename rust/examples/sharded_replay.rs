//! The sharded concurrent cache front: replay the Fig 3 trace on 1, 2, 4
//! and 8 shards, each shard driven by its own scoped worker thread, and
//! print the merged stats. With 1 shard the result is identical to the
//! sequential replay — the parity the property tests pin down.
//!
//! ```text
//! cargo run --release --example sharded_replay
//! ```

use anyhow::Result;

use h_svm_lru::experiments::sharded_replay;
use h_svm_lru::util::bytes::MB;
use h_svm_lru::workload::fig3_trace;

fn main() -> Result<()> {
    let block_size = 64 * MB;
    let capacity = 8 * block_size;
    let trace = fig3_trace(block_size, 20230101);
    println!(
        "sharded replay: {} requests, 8-block cache, h-svm-lru per shard",
        trace.len()
    );

    // One classifier pass shared by every shard count.
    let reports = sharded_replay::run_sweep("h-svm-lru", &[1, 2, 4, 8], capacity, &trace)?;
    print!("{}", sharded_replay::render(&reports).render());

    let one = &reports[0];
    for r in &reports {
        anyhow::ensure!(
            r.stats.requests == trace.len() as u64,
            "{} shards replayed {} of {} requests",
            r.shards,
            r.stats.requests,
            trace.len()
        );
    }
    println!(
        "\nOK: every shard count replayed the full trace (1-shard hit ratio {:.4}).",
        one.hit_ratio()
    );
    Ok(())
}
