//! Operate the simulated cluster like a cluster: Poisson job arrivals over
//! shared datasets, heartbeats with cache reports, online SVM retraining —
//! the `repro simulate` path as a library call.
//!
//! ```text
//! cargo run --release --example cluster_simulation
//! ```

use anyhow::Result;

use h_svm_lru::config::{ClusterConfig, SvmConfig};
use h_svm_lru::experiments::simulate::{self, SimulateConfig};
use h_svm_lru::experiments::Scenario;

fn main() -> Result<()> {
    let cluster = ClusterConfig { datanodes: 3, replication: 2, ..Default::default() };
    let svm = SvmConfig { backend: "rust".into(), ..Default::default() };
    let sim = SimulateConfig { n_jobs: 12, ..Default::default() };
    let report = simulate::run(&cluster, &Scenario::SvmLru, &svm, &sim)?;

    println!("\n=== cluster simulation (H-SVM-LRU, 3 DataNodes) ===");
    println!("jobs completed     {}", report.completed.len());
    println!("sim time           {}", report.sim_end);
    println!("events fired       {}", report.events_fired);
    println!("hit ratio          {:.4}", report.hit_ratio);
    println!("byte hit ratio     {:.4}", report.byte_hit_ratio);
    println!("heartbeats         {}", report.heartbeats);
    println!("metadata fixes     {}", report.metadata_fixes);
    println!("svm trainings      {}", report.trainings);

    anyhow::ensure!(report.completed.len() == 12, "all jobs must complete");
    anyhow::ensure!(report.metadata_fixes == 0, "cache metadata drifted");
    println!("\nOK: simulation completed with consistent cache metadata.");
    Ok(())
}
