//! 30-second tour (no AOT artifacts needed): replay the Fig 3 pollution
//! trace at one cache size and print LRU vs H-SVM-LRU hit ratios plus
//! classifier stats. CI runs this as a smoke test for the user-facing API.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;

use h_svm_lru::config::SvmConfig;
use h_svm_lru::experiments::common::provision_fig3_cluster;
use h_svm_lru::experiments::{make_coordinator, replay_trace_two_pass, Scenario};
use h_svm_lru::util::bytes::MB;
use h_svm_lru::workload::fig3_trace;

fn main() -> Result<()> {
    let svm_cfg = SvmConfig { backend: "rust".into(), ..Default::default() };
    let seed = 20230101;
    println!("h-svm-lru quickstart: 2GB input, 8-block cache, 64MB blocks");
    println!("svm backend: {} / kernel {}", svm_cfg.backend, svm_cfg.kernel);
    let trace = fig3_trace(64 * MB, seed);
    println!("trace: {} requests over 32 hot blocks + pollution stream", trace.len());

    let mut ratios = Vec::new();
    for scenario in [Scenario::Policy("lru".to_string()), Scenario::SvmLru] {
        let (_cfg, cluster) = provision_fig3_cluster(64 * MB, 8, seed);
        let mut coord = make_coordinator(cluster, &scenario, &svm_cfg)?;
        let hit_ratio = replay_trace_two_pass(&mut coord, &trace)?;
        println!(
            "{:<12} hit ratio {:.4}   (hits {} / misses {} / evictions {})",
            scenario.label(),
            hit_ratio,
            coord.stats.hits,
            coord.stats.misses,
            coord.stats.evictions,
        );
        if scenario == Scenario::SvmLru {
            let bs = coord.batcher_stats();
            println!(
                "  classifier: {} trainings, {} queries, {} class-cache hits, {} backend calls",
                coord.pipeline.trainings, bs.queries, bs.class_cache_hits, bs.backend_calls
            );
        }
        ratios.push(hit_ratio);
    }
    anyhow::ensure!(
        ratios[1] >= ratios[0],
        "H-SVM-LRU ({:.4}) must not lose to LRU ({:.4}) on the pollution trace",
        ratios[1],
        ratios[0]
    );
    println!("\nOK: H-SVM-LRU dominates LRU on the cache-pollution trace.");
    Ok(())
}
